//! A minimal, dependency-free implementation of the `log` crate facade —
//! just the API subset kiwi uses (`error!`..`trace!`, `set_logger`,
//! `set_max_level`, the [`Log`] trait). Vendored so the workspace builds
//! with no network access; drop-in replaceable by the real crate.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity levels, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `pad` (not `write_str`) so width/alignment specs like `{:<5}` work.
        f.pad(self.as_str())
    }
}

/// A verbosity ceiling: `Off` silences everything; otherwise messages at
/// or below the named level pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (level + target module path).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A destination for log records.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when `set_logger` is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // LevelFilter::Off

/// Install the global logger. Fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static HITS: AtomicU64 = AtomicU64::new(0);

    struct CountingLogger;

    impl Log for CountingLogger {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            let _ = format!("{} {} {}", record.level(), record.target(), record.args());
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_order_against_filters() {
        assert!(Level::Error <= LevelFilter::Warn);
        assert!(Level::Warn <= LevelFilter::Warn);
        assert!(Level::Info > LevelFilter::Warn);
        assert!(Level::Trace <= LevelFilter::Trace);
    }

    #[test]
    fn macros_respect_max_level() {
        set_logger(&CountingLogger).ok();
        set_max_level(LevelFilter::Off);
        crate::warn!("dropped {}", 1);
        let before = HITS.load(Ordering::Relaxed);
        set_max_level(LevelFilter::Info);
        crate::info!("kept {}", 2);
        crate::debug!("dropped again");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 1);
        set_max_level(LevelFilter::Off);
    }
}
