//! E8 — memory-bounded deep queues (paper §IV: big-data workflows park
//! "large numbers of messages" behind slow consumers; the broker must not
//! trade that backlog for its own heap).
//!
//! Three questions:
//!
//! * **E8a — bounded backlog**: wedge the consumer, publish a deep 1 KiB
//!   backlog into one durable queue, and watch the paging machinery hold
//!   resident queue bytes at `page_out_threshold` while the tail rides the
//!   WAL. Process RSS (from `/proc/self/statm`) must stay under a budget
//!   that is a small multiple of the threshold — *not* of the backlog.
//! * **E8b — zero-loss drain**: un-wedge the consumer and drain the whole
//!   backlog through the page-in path; every message must come back.
//! * **E8c — no-backlog tax**: with a shallow queue the paging code must
//!   be pure bookkeeping; compare publish+drain throughput with paging
//!   enabled (untripped) vs compiled-out (`page_out_threshold = 0`) and
//!   gate on <5% regression (printed, not asserted: CI hardware varies,
//!   the series file is the judge).
//!
//! `KIWI_BENCH_SMOKE=1` shrinks the backlog for CI; `KIWI_BENCH_RECORD=1`
//! appends the run to `../BENCH_memory_bound.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::core::{process_rss_bytes, BrokerConfig, BrokerHandle};
use kiwi::broker::persistence::{
    NoopPersister, PersistBackend, RecoveredState, SegmentedWal, SyncPolicy,
};
use kiwi::broker::protocol::{ClientRequest, MessageProps, QueueOptions, ServerMsg};
use kiwi::wire::{json, Bytes, Value};

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

const MIB: u64 = 1024 * 1024;

fn body_1kib() -> Bytes {
    Bytes::encode(&Value::map([("data", Value::Bytes(vec![0x5A; 1024]))]))
}

fn declare(broker: &BrokerHandle, queue: &str, durable: bool) {
    let (tx, _rx) = std::sync::mpsc::channel();
    let conn = broker.connect("bench-declare", 0, tx);
    broker
        .handle(
            conn,
            &ClientRequest::QueueDeclare {
                queue: queue.into(),
                options: QueueOptions { durable, ..Default::default() },
            },
        )
        .unwrap();
    broker.disconnect(conn);
}

fn publish_n(broker: &BrokerHandle, queue: &str, durable: bool, n: usize) -> Duration {
    let body = body_1kib();
    let (tx, _rx) = std::sync::mpsc::channel();
    let conn = broker.connect("bench-pub", 0, tx);
    let t0 = Instant::now();
    for _ in 0..n {
        broker
            .handle(
                conn,
                &ClientRequest::Publish {
                    exchange: "".into(),
                    routing_key: queue.into(),
                    body: body.clone(),
                    props: MessageProps { persistent: durable, ..Default::default() }.into(),
                    mandatory: true,
                },
            )
            .unwrap();
    }
    let wall = t0.elapsed();
    broker.disconnect(conn);
    wall
}

/// Consume-and-ack the whole queue with a bounded prefetch (so the drain
/// itself cannot balloon memory) and return `(received, wall)`.
fn drain(broker: &BrokerHandle, queue: &str, expect: usize) -> (usize, Duration) {
    let (tx, rx) = std::sync::mpsc::channel();
    let conn = broker.connect("bench-drain", 0, tx);
    broker
        .handle(
            conn,
            &ClientRequest::Consume {
                queue: queue.into(),
                consumer_tag: "drain".into(),
                prefetch: 256,
            },
        )
        .unwrap();
    let t0 = Instant::now();
    let mut received = 0usize;
    while received < expect {
        let msg = match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(m) => m,
            Err(_) => break,
        };
        let tags: Vec<u64> = match msg {
            ServerMsg::Deliver(d) => vec![d.delivery_tag],
            ServerMsg::DeliverBatch(ds) => ds.iter().map(|d| d.delivery_tag).collect(),
            _ => continue,
        };
        for tag in tags {
            received += 1;
            broker.handle(conn, &ClientRequest::Ack { delivery_tag: tag }).unwrap();
        }
    }
    let wall = t0.elapsed();
    broker.disconnect(conn);
    (received, wall)
}

fn wal_broker(tag: &str, config: BrokerConfig) -> (BrokerHandle, std::path::PathBuf) {
    let dir =
        std::env::temp_dir().join(format!("kiwi-bench-membound-{}-{tag}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (wal, rec) =
        SegmentedWal::open(&dir, config.shards, SyncPolicy::Os, Duration::from_micros(500))
            .unwrap();
    let backend: Arc<dyn PersistBackend> = Arc::new(wal);
    (BrokerHandle::with_backend(backend, rec, config), dir)
}

/// E8c helper: shallow publish+drain cycle throughput (transient queue,
/// no WAL, so the measurement isolates the paging bookkeeping itself).
fn shallow_cycle_rate(page_out_threshold: usize, msgs: usize) -> f64 {
    let config = BrokerConfig { page_out_threshold, ..Default::default() };
    let broker = BrokerHandle::with_config(
        Box::new(NoopPersister),
        RecoveredState::default(),
        config,
    );
    declare(&broker, "shallow", false);
    let t0 = Instant::now();
    let publish = publish_n(&broker, "shallow", false, msgs);
    let (received, _) = drain(&broker, "shallow", msgs);
    assert_eq!(received, msgs, "shallow cycle must not lose messages");
    let _ = publish;
    msgs as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let smoke = smoke();
    // Full run: a 2 GiB backlog held at a 64 MiB resident budget — the
    // soak the memory-bounding work is pinned by. Smoke keeps the same
    // shape at 1/100 scale so CI exercises every path in seconds.
    let backlog_msgs: usize = if smoke { 20_000 } else { 2_000_000 };
    let threshold: u64 = if smoke { 2 * MIB } else { 64 * MIB };
    // RSS may grow by the resident window, WAL write buffers, allocator
    // slack and the (unpaged) per-message envelopes — but never by
    // anything proportional to the paged backlog.
    let rss_budget: u64 = 4 * threshold + 192 * MIB + (backlog_msgs as u64 * 256);

    let config = BrokerConfig {
        page_out_threshold: threshold as usize,
        page_in_batch: 64,
        ..Default::default()
    };
    let (broker, dir) = wal_broker("backlog", config);
    declare(&broker, "deep", true);

    // E8a: wedged consumer — publish the whole backlog with nobody
    // draining it.
    let rss_before = process_rss_bytes().unwrap_or(0);
    let publish_wall = publish_n(&broker, "deep", true, backlog_msgs);
    broker.sync().unwrap();
    let rss_peak = process_rss_bytes().unwrap_or(0);
    let rss_growth = rss_peak.saturating_sub(rss_before);
    let resident = broker.queue_resident_bytes("deep").unwrap_or(0);
    let paged = broker.queue_paged("deep").unwrap_or(0);
    let page_outs = broker.metrics().counter("broker.page_outs_total").get();

    let mut e8a = Table::new(
        "E8a memory bound: wedged-consumer backlog (1KiB msgs)",
        &["metric", "value"],
    );
    e8a.row(&["backlog msgs".into(), backlog_msgs.to_string()]);
    e8a.row(&["backlog bytes".into(), format!("{} MiB", backlog_msgs as u64 / 1024)]);
    e8a.row(&["page_out_threshold".into(), format!("{} MiB", threshold / MIB)]);
    e8a.row(&["resident bytes".into(), resident.to_string()]);
    e8a.row(&["paged msgs".into(), paged.to_string()]);
    e8a.row(&["page_outs_total".into(), page_outs.to_string()]);
    e8a.row(&["publish wall".into(), format!("{publish_wall:.2?}")]);
    e8a.row(&[
        "publish msgs/s".into(),
        format!("{:.0}", backlog_msgs as f64 / publish_wall.as_secs_f64()),
    ]);
    e8a.row(&["rss before".into(), format!("{} MiB", rss_before / MIB)]);
    e8a.row(&["rss after backlog".into(), format!("{} MiB", rss_peak / MIB)]);
    e8a.row(&["rss growth".into(), format!("{} MiB", rss_growth / MIB)]);
    e8a.row(&["rss budget".into(), format!("{} MiB", rss_budget / MIB)]);
    e8a.emit();

    assert!(paged > 0, "a backlog this deep must page out");
    assert!(
        resident <= threshold,
        "resident bytes ({resident}) must respect the threshold ({threshold})"
    );
    if rss_before > 0 {
        assert!(
            rss_growth <= rss_budget,
            "RSS grew {rss_growth} bytes holding a paged backlog; budget {rss_budget}"
        );
    }

    // E8b: un-wedge and drain everything back through the page-in path.
    let (received, drain_wall) = drain(&broker, "deep", backlog_msgs);
    let page_ins = broker.metrics().counter("broker.page_ins_total").get();
    let mut e8b = Table::new("E8b memory bound: full drain after paging", &["metric", "value"]);
    e8b.row(&["received".into(), received.to_string()]);
    e8b.row(&["expected".into(), backlog_msgs.to_string()]);
    e8b.row(&["page_ins_total".into(), page_ins.to_string()]);
    e8b.row(&["drain wall".into(), format!("{drain_wall:.2?}")]);
    e8b.row(&[
        "drain msgs/s".into(),
        format!("{:.0}", received as f64 / drain_wall.as_secs_f64().max(1e-9)),
    ]);
    e8b.emit();
    assert_eq!(received, backlog_msgs, "every paged message must survive the round-trip");
    assert_eq!(broker.queue_depth("deep"), Some(0), "the drain must empty the queue");
    assert_eq!(broker.queue_paged("deep"), Some(0), "nothing may stay paged after the drain");
    assert!(page_ins > 0, "the drain must exercise the page-in path");
    drop(broker);
    std::fs::remove_dir_all(&dir).ok();

    // E8c: paging-enabled-but-untripped vs paging-disabled throughput.
    let tax_msgs: usize = if smoke { 5_000 } else { 100_000 };
    let rate_off = shallow_cycle_rate(0, tax_msgs);
    let rate_on = shallow_cycle_rate(usize::MAX / 2, tax_msgs);
    let tax = 1.0 - rate_on / rate_off;
    let mut e8c = Table::new(
        "E8c memory bound: no-backlog paging tax (transient queue)",
        &["paging", "msgs", "msgs/s"],
    );
    e8c.row(&["disabled".into(), tax_msgs.to_string(), format!("{rate_off:.0}")]);
    e8c.row(&["enabled-untripped".into(), tax_msgs.to_string(), format!("{rate_on:.0}")]);
    e8c.emit();
    println!("gate: no-backlog paging tax = {:.1}% (want < 5%)", tax * 100.0);

    let run = Value::map([
        ("bench", Value::from("memory_bound")),
        ("smoke", Value::from(smoke)),
        ("backlog_msgs", Value::from(backlog_msgs)),
        ("threshold_bytes", Value::from(threshold)),
        ("resident_bytes", Value::from(resident)),
        ("paged_msgs", Value::from(paged)),
        ("page_outs", Value::from(page_outs)),
        ("page_ins", Value::from(page_ins)),
        ("rss_growth_bytes", Value::from(rss_growth)),
        ("rss_budget_bytes", Value::from(rss_budget)),
        ("publish_msgs_per_sec", Value::F64(backlog_msgs as f64 / publish_wall.as_secs_f64())),
        (
            "drain_msgs_per_sec",
            Value::F64(received as f64 / drain_wall.as_secs_f64().max(1e-9)),
        ),
        ("no_backlog_rate_off", Value::F64(rate_off)),
        ("no_backlog_rate_on", Value::F64(rate_on)),
        ("no_backlog_tax", Value::F64(tax)),
    ]);
    let path = std::path::Path::new("target/bench-results/BENCH_memory_bound.json");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(path, json::to_string(&run)) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    if std::env::var("KIWI_BENCH_RECORD").is_ok_and(|v| !v.is_empty() && v != "0") {
        let series_path = std::path::Path::new("../BENCH_memory_bound.json");
        let mut series = std::fs::read_to_string(series_path)
            .ok()
            .and_then(|t| json::from_str(&t).ok())
            .unwrap_or_else(|| {
                Value::map([
                    ("bench", Value::from("memory_bound")),
                    ("runs", Value::List(Vec::new())),
                ])
            });
        if let Value::Map(m) = &mut series {
            let runs = m.entry("runs".to_string()).or_insert_with(|| Value::List(Vec::new()));
            if let Value::List(list) = runs {
                list.push(run);
            }
        }
        match std::fs::write(series_path, json::to_string_pretty(&series)) {
            Ok(()) => println!("recorded run into {}", series_path.display()),
            Err(e) => eprintln!("warning: could not record series: {e}"),
        }
    }
}
