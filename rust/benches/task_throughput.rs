//! E1 — task-queue throughput (paper §I: "high-volume ... predictable").
//!
//! Sweep workers × payload size over the embedded broker; report
//! end-to-end completed tasks/second (submit → handler → ack → reply).

use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::InprocBroker;
use kiwi::communicator::{Communicator, RmqCommunicator, RmqConfig, TaskHandler};
use kiwi::wire::Value;

const TASKS: usize = 2_000;

fn run_case(workers: usize, payload_bytes: usize, confirm: bool) -> (f64, Duration, f64) {
    let broker = InprocBroker::new();
    let client = RmqCommunicator::connect(
        broker.connect(),
        RmqConfig { confirm_publishes: confirm, ..Default::default() },
    )
    .unwrap();
    let mut worker_comms = Vec::new();
    for _ in 0..workers {
        let comm = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
        let handler: TaskHandler = Box::new(move |_task, ctx| {
            ctx.complete(Ok(Value::Null));
        });
        comm.task_queue("bench.tasks", 4, handler).unwrap();
        worker_comms.push(comm);
    }
    let payload = Value::map([("data", Value::Bytes(vec![0xAB; payload_bytes]))]);
    let bytes_in_before = broker.broker().metrics().counter("broker.bytes_in_total").get();
    let t0 = Instant::now();
    let futs: Vec<_> = (0..TASKS)
        .map(|_| client.task_send("bench.tasks", payload.clone()).unwrap())
        .collect();
    for f in futs {
        f.wait(Duration::from_secs(120)).unwrap();
    }
    let elapsed = t0.elapsed();
    let ingress = broker.broker().metrics().counter("broker.bytes_in_total").get()
        - bytes_in_before;
    (
        TASKS as f64 / elapsed.as_secs_f64(),
        elapsed,
        ingress as f64 / 1e6 / elapsed.as_secs_f64(),
    )
}

fn main() {
    let mut table = Table::new(
        "E1 task-queue throughput (2000 tasks, inproc broker)",
        &["workers", "payload", "confirms", "tasks/s", "wall", "ingress MB/s"],
    );
    for &workers in &[1usize, 2, 4, 8] {
        for &(payload, label) in &[(64usize, "64B"), (4096, "4KiB"), (65536, "64KiB")] {
            for &confirm in &[true, false] {
                let (thpt, wall, mb_s) = run_case(workers, payload, confirm);
                table.row(&[
                    workers.to_string(),
                    label.to_string(),
                    if confirm { "on" } else { "off" }.to_string(),
                    format!("{thpt:.0}"),
                    format!("{wall:.2?}"),
                    format!("{mb_s:.1}"),
                ]);
            }
        }
    }
    table.emit();
    println!("expected shape: confirms-off removes one RTT per submission\n\
              (pipelined); payload cost is one encode at the sender and one\n\
              decode at the worker — the broker/WAL never re-encode; worker\n\
              count is neutral when the handler is trivial (client-bound).");
}
