//! §Scale — connection storm: the epoll reactor front-end under
//! thousands of concurrent connections with churn.
//!
//! Phases:
//!
//! 1. **Storm** — dial N connections from 16 threads, complete the Hello
//!    handshake on each and keep them parked. Reports accept+Hello RTT
//!    p50/p99, accepts/sec and resident-memory delta per connection.
//! 2. **Churn** — open/handshake/Goodbye/close cycles on top of the
//!    parked fleet; reports cycle p99 and that the process fd count
//!    returns to its pre-churn baseline (no leaked sockets).
//! 3. **Throughput** — one publisher → one consumer pumping messages
//!    across a queue while the idle fleet stays parked; reports msgs/sec
//!    (idle connections must not tax the data path).
//!
//! Also records the process thread count before/after the fleet: the
//! reactor front-end must stay O(shards + reactor), not O(connections).
//!
//! Emits the usual table + CSV and a machine-readable
//! `target/bench-results/BENCH_connection_storm.json`. With
//! `KIWI_BENCH_RECORD=1` the run is appended to the tracked trajectory
//! series at the repository root (`BENCH_connection_storm.json`).
//!
//! `KIWI_BENCH_SMOKE=1` shrinks the fleet so CI can run this as a
//! regression tripwire; `KIWI_NET=threads` exercises the legacy
//! front-end for comparison.

use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::core::BrokerHandle;
use kiwi::broker::protocol::{ClientRequest, QueueOptions, ServerMsg};
use kiwi::broker::reactor;
use kiwi::broker::server::{BrokerServer, NetOptions};
use kiwi::metrics::Histogram;
use kiwi::wire::{json, read_frame, write_frame, Bytes, FrameType, Value};

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Parse a `Key: value kB`-style line out of /proc/self/status.
fn proc_status_field(key: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let rest = rest.trim_start_matches(':').trim();
            let first = rest.split_whitespace().next()?;
            return first.parse().ok();
        }
    }
    None
}

fn rss_kb() -> u64 {
    proc_status_field("VmRSS").unwrap_or(0)
}

fn thread_count() -> u64 {
    proc_status_field("Threads").unwrap_or(0)
}

fn fd_count() -> usize {
    std::fs::read_dir("/proc/self/fd").map(|d| d.count()).unwrap_or(0)
}

fn send(stream: &TcpStream, req: &ClientRequest, id: u64) {
    let mut w = stream;
    write_frame(&mut w, &req.to_frame(id)).expect("send frame");
}

fn recv_data(stream: &TcpStream) -> ServerMsg {
    let mut r = stream;
    loop {
        let f = read_frame(&mut r).expect("recv frame");
        if f.frame_type == FrameType::Data {
            return ServerMsg::from_frame(&f).expect("decode server msg");
        }
    }
}

/// Dial + Hello handshake, with a few retries to ride out SYN-backlog
/// pressure during the storm. Returns the stream and the handshake RTT.
fn dial(addr: SocketAddr, id: u64) -> (TcpStream, Duration) {
    let mut attempt = 0;
    loop {
        let t0 = Instant::now();
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                send(
                    &stream,
                    &ClientRequest::Hello { client_id: format!("storm-{id}"), heartbeat_ms: 0 },
                    1,
                );
                match recv_data(&stream) {
                    ServerMsg::Ok { .. } => return (stream, t0.elapsed()),
                    other => panic!("hello rejected: {other:?}"),
                }
            }
            Err(e) => {
                attempt += 1;
                assert!(attempt < 50, "connect kept failing: {e}");
                std::thread::sleep(Duration::from_millis(10 * attempt));
            }
        }
    }
}

fn main() {
    let smoke = smoke();
    // Each parked connection is two fds in this process (client + broker
    // side). Ask for headroom, then size the fleet to what we got.
    let fleet_target: usize = if smoke { 256 } else { 10_000 };
    let nofile = reactor::raise_nofile_limit(65_536).unwrap_or(1024);
    let fleet: usize = fleet_target.min(((nofile.saturating_sub(256)) / 3) as usize).max(8);
    let churn_cycles: usize = if smoke { 128 } else { 2_000 };
    let messages: usize = if smoke { 2_000 } else { 50_000 };
    let dialers: usize = 16;

    let opts = NetOptions::from_env();
    let server = BrokerServer::start_with(BrokerHandle::new(), "127.0.0.1:0", opts)
        .expect("start broker server");
    let addr = server.addr();
    println!(
        "connection storm: {:?} front-end, fleet={fleet} (nofile={nofile}), \
         churn={churn_cycles}, messages={messages}",
        server.net_mode()
    );

    let threads_before = thread_count();
    let rss_before = rss_kb();

    // ---- Phase 1: the storm ----
    let storm_t0 = Instant::now();
    let mut workers = Vec::new();
    for w in 0..dialers {
        let lo = fleet * w / dialers;
        let hi = fleet * (w + 1) / dialers;
        workers.push(std::thread::spawn(move || {
            let mut conns = Vec::with_capacity(hi - lo);
            let mut rtts = Vec::with_capacity(hi - lo);
            for i in lo..hi {
                let (stream, rtt) = dial(addr, i as u64);
                conns.push(stream);
                rtts.push(rtt);
            }
            (conns, rtts)
        }));
    }
    let mut fleet_conns: Vec<TcpStream> = Vec::with_capacity(fleet);
    let connect_hist = Histogram::new();
    for w in workers {
        let (conns, rtts) = w.join().expect("dialer panicked");
        fleet_conns.extend(conns);
        for rtt in rtts {
            connect_hist.record_duration(rtt);
        }
    }
    let storm_elapsed = storm_t0.elapsed();
    let accepts_per_sec = fleet as f64 / storm_elapsed.as_secs_f64().max(1e-9);
    let threads_after = thread_count();
    let rss_after = rss_kb();
    let rss_delta = rss_after.saturating_sub(rss_before);
    let rss_per_conn_kb = rss_delta as f64 / fleet as f64;

    // ---- Phase 2: churn on top of the parked fleet ----
    let fd_baseline = fd_count();
    let churn_hist = Histogram::new();
    for i in 0..churn_cycles {
        let t0 = Instant::now();
        let (stream, _) = dial(addr, (fleet + i) as u64);
        send(&stream, &ClientRequest::Close, 2);
        let _ = recv_data(&stream);
        drop(stream);
        churn_hist.record_duration(t0.elapsed());
    }
    // Give teardown a moment, then verify fds returned to baseline
    // (small slack for transient /proc entries).
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut fd_after = fd_count();
    while fd_after > fd_baseline + 4 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
        fd_after = fd_count();
    }

    // ---- Phase 3: throughput with the fleet parked ----
    let (publisher, _) = dial(addr, 900_000);
    let (consumer, _) = dial(addr, 900_001);
    send(
        &publisher,
        &ClientRequest::QueueDeclare { queue: "storm".into(), options: QueueOptions::default() },
        3,
    );
    let _ = recv_data(&publisher);
    send(
        &consumer,
        &ClientRequest::Consume { queue: "storm".into(), consumer_tag: "c".into(), prefetch: 0 },
        4,
    );
    let _ = recv_data(&consumer);
    let body = Bytes::encode(&Value::Bytes(vec![0x5a; 256]));
    let pump_t0 = Instant::now();
    let pub_handle = {
        let publisher = publisher.try_clone().expect("clone publisher");
        let body = body.clone();
        std::thread::spawn(move || {
            for i in 0..messages {
                send(
                    &publisher,
                    &ClientRequest::Publish {
                        exchange: "".into(),
                        routing_key: "storm".into(),
                        body: body.clone(),
                        props: Default::default(),
                        mandatory: false,
                    },
                    10 + i as u64,
                );
            }
        })
    };
    let mut received = 0usize;
    while received < messages {
        match recv_data(&consumer) {
            ServerMsg::Deliver(_) => received += 1,
            ServerMsg::DeliverBatch(ds) => received += ds.len(),
            ServerMsg::Ok { .. } => {}
            other => panic!("unexpected during pump: {other:?}"),
        }
    }
    pub_handle.join().expect("publisher panicked");
    let pump_elapsed = pump_t0.elapsed();
    let msgs_per_sec = messages as f64 / pump_elapsed.as_secs_f64().max(1e-9);

    // ---- Teardown the fleet before reporting ----
    drop(publisher);
    drop(consumer);
    drop(fleet_conns);

    let fmt_ns = |ns: u64| format!("{:.2?}", Duration::from_nanos(ns));
    let mut table = Table::new(
        "connection_storm",
        &["metric", "value"],
    );
    table.row(&["net_mode".into(), format!("{:?}", server.net_mode())]);
    table.row(&["fleet".into(), fleet.to_string()]);
    table.row(&["connect_p50".into(), fmt_ns(connect_hist.quantile(0.5))]);
    table.row(&["connect_p99".into(), fmt_ns(connect_hist.quantile(0.99))]);
    table.row(&["accepts_per_sec".into(), format!("{accepts_per_sec:.0}")]);
    table.row(&["rss_delta_kb".into(), rss_delta.to_string()]);
    table.row(&["rss_per_conn_kb".into(), format!("{rss_per_conn_kb:.1}")]);
    table.row(&["threads_before".into(), threads_before.to_string()]);
    table.row(&["threads_with_fleet".into(), threads_after.to_string()]);
    table.row(&["churn_cycles".into(), churn_cycles.to_string()]);
    table.row(&["churn_p99".into(), fmt_ns(churn_hist.quantile(0.99))]);
    table.row(&["fd_baseline".into(), fd_baseline.to_string()]);
    table.row(&["fd_after_churn".into(), fd_after.to_string()]);
    table.row(&["msgs_per_sec".into(), format!("{msgs_per_sec:.0}")]);
    table.emit();

    // Tripwires the CI smoke run can catch without measuring anything:
    // churned fds must come back, and an O(connections) thread model
    // would show up as fleet-sized thread growth in reactor mode.
    println!(
        "gate: fd_after_churn={} fd_baseline={} (leak if it keeps growing)",
        fd_after, fd_baseline
    );
    println!(
        "gate: thread growth with {} parked conns: {} -> {}",
        fleet, threads_before, threads_after
    );

    let run = Value::map([
        ("bench", Value::from("connection_storm")),
        ("smoke", Value::from(smoke)),
        ("net_mode", Value::from(format!("{:?}", server.net_mode()))),
        ("fleet", Value::from(fleet)),
        ("connect_p50_ns", Value::from(connect_hist.quantile(0.5))),
        ("connect_p99_ns", Value::from(connect_hist.quantile(0.99))),
        ("accepts_per_sec", Value::from(accepts_per_sec)),
        ("rss_delta_kb", Value::from(rss_delta)),
        ("rss_per_conn_kb", Value::from(rss_per_conn_kb)),
        ("threads_before", Value::from(threads_before)),
        ("threads_with_fleet", Value::from(threads_after)),
        ("churn_cycles", Value::from(churn_cycles)),
        ("churn_p99_ns", Value::from(churn_hist.quantile(0.99))),
        ("fd_baseline", Value::from(fd_baseline)),
        ("fd_after_churn", Value::from(fd_after)),
        ("msgs_per_sec", Value::from(msgs_per_sec)),
    ]);
    let path = std::path::Path::new("target/bench-results/BENCH_connection_storm.json");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(path, json::to_string(&run)) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    // Tracked trajectory series at the repo root: append this run when
    // recording is requested (benches run from rust/, the series lives
    // one level up).
    if std::env::var("KIWI_BENCH_RECORD").is_ok_and(|v| !v.is_empty() && v != "0") {
        let series_path = std::path::Path::new("../BENCH_connection_storm.json");
        let mut series = std::fs::read_to_string(series_path)
            .ok()
            .and_then(|t| json::from_str(&t).ok())
            .unwrap_or_else(|| {
                Value::map([
                    ("bench", Value::from("connection_storm")),
                    ("runs", Value::List(Vec::new())),
                ])
            });
        if let Value::Map(m) = &mut series {
            let runs = m.entry("runs".to_string()).or_insert_with(|| Value::List(Vec::new()));
            if let Value::List(list) = runs {
                list.push(run);
            }
        }
        match std::fs::write(series_path, json::to_string_pretty(&series)) {
            Ok(()) => println!("recorded run into {}", series_path.display()),
            Err(e) => eprintln!("warning: could not record series: {e}"),
        }
    }

    server.shutdown();
}
