//! §Perf — wire codec microbenchmark: encode/decode/clone cost of the
//! message shapes that dominate the hot path (small control maps, 64 KiB
//! blob tasks, 12 KiB f32 tensors). Drives the §Perf iteration log in
//! EXPERIMENTS.md.

use std::time::Duration;

use kiwi::benchutil::{bench, Table};
use kiwi::wire::{codec, Value};

fn throughput_mb(bytes: usize, r: &kiwi::benchutil::BenchResult) -> String {
    let mb = bytes as f64 * r.iterations as f64 / 1e6;
    format!("{:.0} MB/s", mb / r.total.as_secs_f64())
}

fn main() {
    let small = Value::map([
        ("op", Value::str("publish")),
        ("req_id", Value::I64(12345)),
        ("routing_key", Value::str("kiwi.tasks")),
        ("mandatory", Value::Bool(true)),
    ]);
    let blob = Value::map([("data", Value::Bytes(vec![0xAB; 64 * 1024]))]);
    let tensor = Value::map([("positions", Value::F32s(vec![1.5f32; 3 * 1024]))]);

    let mut table = Table::new(
        "Perf: wire codec microbench",
        &["case", "op", "mean", "throughput"],
    );
    let target = Duration::from_millis(300);
    for (name, value, payload_bytes) in [
        ("small map", &small, 64usize),
        ("64KiB bytes", &blob, 64 * 1024),
        ("12KiB f32s", &tensor, 12 * 1024),
    ] {
        let encoded = codec::encode_to_vec(value);
        let r = bench("encode", target, || {
            std::hint::black_box(codec::encode_to_vec(std::hint::black_box(value)));
        });
        table.row(&[
            name.into(),
            "encode".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(payload_bytes, &r),
        ]);
        let r = bench("decode", target, || {
            std::hint::black_box(codec::decode(std::hint::black_box(&encoded)).unwrap());
        });
        table.row(&[
            name.into(),
            "decode".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(payload_bytes, &r),
        ]);
        let r = bench("clone", target, || {
            std::hint::black_box(std::hint::black_box(value).clone());
        });
        table.row(&[
            name.into(),
            "clone".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(payload_bytes, &r),
        ]);
    }
    table.emit();
}
