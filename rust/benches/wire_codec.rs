//! §Perf — wire codec microbenchmark: encode/decode/clone cost of the
//! message shapes that dominate the hot path (small control maps, 64 KiB
//! blob tasks, 12 KiB f32 tensors), plus a payload-size sweep
//! (1 KiB / 64 KiB / 1 MiB) that tracks the zero-copy path: one encode
//! into `Bytes`, O(1) refcount clones for every fanout copy, one decode at
//! the consumer. Drives the §Perf iteration log in EXPERIMENTS.md; the
//! sweep CSV is the perf-trajectory artifact the CI smoke job regenerates.
//!
//! `KIWI_BENCH_SMOKE=1` shrinks the measurement budget so CI can run this
//! as a regression tripwire rather than a measurement.

use std::time::Duration;

use kiwi::benchutil::{bench, Table};
use kiwi::wire::{codec, Bytes, Value};

fn throughput_mb(bytes: usize, r: &kiwi::benchutil::BenchResult) -> String {
    let mb = bytes as f64 * r.iterations as f64 / 1e6;
    format!("{:.0} MB/s", mb / r.total.as_secs_f64())
}

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

fn main() {
    let target = if smoke() { Duration::from_millis(20) } else { Duration::from_millis(300) };

    let small = Value::map([
        ("op", Value::str("publish")),
        ("req_id", Value::I64(12345)),
        ("routing_key", Value::str("kiwi.tasks")),
        ("mandatory", Value::Bool(true)),
    ]);
    let blob = Value::map([("data", Value::Bytes(vec![0xAB; 64 * 1024]))]);
    let tensor = Value::map([("positions", Value::F32s(vec![1.5f32; 3 * 1024]))]);

    let mut table = Table::new(
        "Perf: wire codec microbench",
        &["case", "op", "mean", "throughput"],
    );
    for (name, value, payload_bytes) in [
        ("small map", &small, 64usize),
        ("64KiB bytes", &blob, 64 * 1024),
        ("12KiB f32s", &tensor, 12 * 1024),
    ] {
        let encoded = codec::encode_to_vec(value);
        let r = bench("encode", target, || {
            std::hint::black_box(codec::encode_to_vec(std::hint::black_box(value)));
        });
        table.row(&[
            name.into(),
            "encode".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(payload_bytes, &r),
        ]);
        let r = bench("decode", target, || {
            std::hint::black_box(codec::decode(std::hint::black_box(&encoded)).unwrap());
        });
        table.row(&[
            name.into(),
            "decode".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(payload_bytes, &r),
        ]);
        let r = bench("clone", target, || {
            std::hint::black_box(std::hint::black_box(value).clone());
        });
        table.row(&[
            name.into(),
            "clone".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(payload_bytes, &r),
        ]);
    }
    table.emit();

    // Payload-size sweep: the old per-recipient cost (value clone + encode)
    // vs the zero-copy path's per-recipient cost (a Bytes refcount bump).
    let mut sweep = Table::new(
        "Perf: payload path sweep",
        &["payload", "op", "mean", "throughput"],
    );
    for (label, size) in [("1KiB", 1024usize), ("64KiB", 64 * 1024), ("1MiB", 1024 * 1024)] {
        let value = Value::map([("data", Value::Bytes(vec![0xCD; size]))]);
        let body = Bytes::encode(&value);

        let r = bench("encode_once", target, || {
            std::hint::black_box(Bytes::encode(std::hint::black_box(&value)));
        });
        sweep.row(&[
            label.into(),
            "encode-once".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(size, &r),
        ]);
        let r = bench("bytes_clone", target, || {
            std::hint::black_box(std::hint::black_box(&body).clone());
        });
        sweep.row(&[
            label.into(),
            "per-recipient share (Bytes clone)".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(size, &r),
        ]);
        let r = bench("value_clone_encode", target, || {
            let v = std::hint::black_box(&value).clone();
            std::hint::black_box(codec::encode_to_vec(&v));
        });
        sweep.row(&[
            label.into(),
            "per-recipient re-encode (old path)".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(size, &r),
        ]);
        let r = bench("decode_at_consumer", target, || {
            std::hint::black_box(std::hint::black_box(&body).decode().unwrap());
        });
        sweep.row(&[
            label.into(),
            "decode-at-consumer".into(),
            format!("{:.2?}", r.mean()),
            throughput_mb(size, &r),
        ]);
    }
    sweep.emit();
    println!("expected shape: encode-once and decode-at-consumer scale with\n\
              payload size; the per-recipient share is O(1) regardless of\n\
              size — that flat line is the fanout win.");
}
