//! E8 — heartbeats (paper §I: two missed checks ⇒ requeue to another
//! client; heartbeats maintained by the hidden communication thread).
//!
//! Measures (a) failure-detection latency: time from a consumer going
//! silent to its task being requeued, vs the negotiated heartbeat
//! interval — the spec says ≈ 2×interval; (b) idle heartbeat traffic.

use std::time::{Duration, Instant};

use kiwi::benchutil::{runner::fmt_dur, Table};
use kiwi::broker::core::BrokerHandle;
use kiwi::broker::heartbeat::HeartbeatMonitor;
use kiwi::broker::protocol::{ClientRequest, MessageProps, QueueOptions, ServerMsg};
use kiwi::wire::Value;

/// A consumer that takes one delivery, then goes silent (no heartbeats, no
/// ack) — the in-process model of a hung worker.
fn detection_latency(heartbeat_ms: u64) -> Duration {
    let broker = BrokerHandle::new();
    let _monitor = HeartbeatMonitor::spawn(broker.clone(), Duration::from_millis(5));

    let (tx, rx) = std::sync::mpsc::channel();
    let conn = broker.connect("hung-worker", heartbeat_ms, tx);
    broker
        .handle(
            conn,
            &ClientRequest::QueueDeclare { queue: "q".into(), options: QueueOptions::default() },
        )
        .unwrap();
    broker
        .handle(
            conn,
            &ClientRequest::Publish {
                exchange: "".into(),
                routing_key: "q".into(),
                body: kiwi::wire::Bytes::encode(&Value::str("work")),
                props: MessageProps::default().into(),
                mandatory: true,
            },
        )
        .unwrap();
    broker
        .handle(
            conn,
            &ClientRequest::Consume { queue: "q".into(), consumer_tag: "c".into(), prefetch: 0 },
        )
        .unwrap();
    // Delivery in flight; now the consumer goes silent.
    assert!(matches!(rx.recv_timeout(Duration::from_secs(2)), Ok(ServerMsg::Deliver(_))));
    let silent_from = Instant::now();
    loop {
        if broker.queue_depth("q") == Some(1) {
            return silent_from.elapsed();
        }
        assert!(silent_from.elapsed() < Duration::from_secs(30), "never evicted");
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn main() {
    let mut table = Table::new(
        "E8 heartbeat failure detection (silent consumer with 1 unacked task)",
        &["heartbeat", "detect+requeue", "ratio to 2x-interval"],
    );
    for &hb in &[50u64, 100, 200, 400] {
        // Median of 3 runs (timers + scan period add jitter).
        let mut runs: Vec<Duration> = (0..3).map(|_| detection_latency(hb)).collect();
        runs.sort();
        let detect = runs[1];
        table.row(&[
            format!("{hb}ms"),
            fmt_dur(detect),
            format!("{:.2}", detect.as_secs_f64() / (2.0 * hb as f64 / 1000.0)),
        ]);
        // Lower bound has a small allowance: last_seen is stamped at the
        // consume request, a hair before our silent_from timer starts.
        assert!(
            detect + Duration::from_millis(20) >= Duration::from_millis(2 * hb),
            "must not evict before two missed heartbeats (got {detect:.2?})"
        );
        assert!(
            detect < Duration::from_millis(2 * hb + 200),
            "detection should track 2x interval closely, got {detect:.2?}"
        );
    }
    table.emit();

    // Idle heartbeat traffic: a live but idle connection for 2 s.
    use kiwi::broker::InprocBroker;
    use kiwi::transport::{Connection, ConnectionConfig};
    let broker = InprocBroker::new();
    let mut traffic = Table::new(
        "E8b idle heartbeat overhead (2s idle connection)",
        &["heartbeat", "broker connects seen", "connection alive"],
    );
    for &hb in &[50u64, 200] {
        let conn = Connection::open(
            broker.connect(),
            ConnectionConfig { heartbeat_ms: hb, ..Default::default() },
        )
        .unwrap();
        std::thread::sleep(Duration::from_secs(2));
        let alive = !conn.is_closed();
        traffic.row(&[format!("{hb}ms"), "1".into(), alive.to_string()]);
        assert!(alive, "idle connection with heartbeats must stay alive");
        conn.close();
    }
    traffic.emit();
    println!("expected shape: detection ≈ 2x heartbeat interval + scan\n\
              jitter (the paper's two-missed-checks rule); idle connections\n\
              survive indefinitely on heartbeats alone.");
}
