//! E3 — broadcast fan-out (paper §I.C: decoupled flow control).
//!
//! One sender, N subscribers; measure time from `broadcast_send` until
//! every subscriber has the message, for N up to 256, filtered and not.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use kiwi::benchutil::{runner::fmt_dur, Table};
use kiwi::broker::InprocBroker;
use kiwi::communicator::{BroadcastFilter, Communicator, RmqCommunicator, RmqConfig};
use kiwi::wire::Value;

const ROUNDS: usize = 100;

struct Gate {
    count: AtomicU64,
    target: u64,
    mx: Mutex<u64>, // generation
    cv: Condvar,
}

fn run_case(subscribers: usize, filtered: bool) -> (Duration, Duration, f64) {
    let broker = InprocBroker::new();
    let sender = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
    let gate = Arc::new(Gate {
        count: AtomicU64::new(0),
        target: subscribers as u64,
        mx: Mutex::new(0),
        cv: Condvar::new(),
    });
    // Keep subscriber communicators alive for the whole case.
    let mut subs = Vec::new();
    for _ in 0..subscribers {
        let comm = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
        let gate2 = Arc::clone(&gate);
        let filter = if filtered {
            // Half the traffic is filtered out subscriber-side.
            BroadcastFilter::all().subject("wanted.*")
        } else {
            BroadcastFilter::all()
        };
        comm.add_broadcast_subscriber(
            filter,
            Box::new(move |_msg| {
                let n = gate2.count.fetch_add(1, Ordering::Relaxed) + 1;
                if n % gate2.target == 0 {
                    let mut generation = gate2.mx.lock().unwrap();
                    *generation += 1;
                    gate2.cv.notify_all();
                }
            }),
        )
        .unwrap();
        subs.push(comm);
    }

    let hist = kiwi::metrics::Histogram::new();
    let t_all = Instant::now();
    for round in 0..ROUNDS {
        let generation_before = *gate.mx.lock().unwrap();
        let t0 = Instant::now();
        if filtered {
            // One dropped message + one wanted message per round.
            sender.broadcast_send(Value::I64(round as i64), None, Some("noise.x")).unwrap();
        }
        sender.broadcast_send(Value::I64(round as i64), None, Some("wanted.x")).unwrap();
        let mut generation = gate.mx.lock().unwrap();
        while *generation <= generation_before {
            let (g, timeout) =
                gate.cv.wait_timeout(generation, Duration::from_secs(30)).unwrap();
            generation = g;
            assert!(!timeout.timed_out(), "fan-out did not complete");
        }
        hist.record_duration(t0.elapsed());
    }
    let msgs = ROUNDS * subscribers;
    (
        Duration::from_nanos(hist.quantile(0.5)),
        Duration::from_nanos(hist.quantile(0.99)),
        msgs as f64 / t_all.elapsed().as_secs_f64(),
    )
}

fn main() {
    let mut table = Table::new(
        "E3 broadcast fan-out (100 rounds, inproc broker)",
        &["subscribers", "filtered", "p50 all-received", "p99", "deliveries/s"],
    );
    for &n in &[1usize, 4, 16, 64, 256] {
        for &filtered in &[false, true] {
            let (p50, p99, thpt) = run_case(n, filtered);
            table.row(&[
                n.to_string(),
                filtered.to_string(),
                fmt_dur(p50),
                fmt_dur(p99),
                format!("{thpt:.0}"),
            ]);
        }
    }
    table.emit();
    println!("expected shape: all-received latency grows ~linearly with\n\
              subscribers (one queue copy each); filtering costs one extra\n\
              dropped delivery per subscriber, not a broker-side scan.");
}
