//! E3 — broadcast fan-out (paper §I.C: decoupled flow control).
//!
//! One sender, N subscribers; measure time from `broadcast_send` until
//! every subscriber has the message — across subscriber counts, filters
//! and payload sizes. With the zero-copy payload path the sender encodes
//! once and every subscriber's delivery shares that buffer, so per-payload
//! cost should be one encode + N decodes, not N re-encodes; MB/s columns
//! come from the broker's `bytes_in_total`/`bytes_out_total` counters.
//!
//! `KIWI_BENCH_SMOKE=1` shrinks rounds and the sweep so CI can run this as
//! a payload-path regression tripwire.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use kiwi::benchutil::{runner::fmt_dur, Table};
use kiwi::broker::InprocBroker;
use kiwi::communicator::{BroadcastFilter, Communicator, RmqCommunicator, RmqConfig};
use kiwi::wire::Value;

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

struct Gate {
    count: AtomicU64,
    target: u64,
    mx: Mutex<u64>, // generation
    cv: Condvar,
}

struct CaseResult {
    p50: Duration,
    p99: Duration,
    deliveries_per_s: f64,
    egress_mb_s: f64,
}

fn run_case(subscribers: usize, payload_bytes: usize, filtered: bool, rounds: usize) -> CaseResult {
    let broker = InprocBroker::new();
    let sender = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
    let gate = Arc::new(Gate {
        count: AtomicU64::new(0),
        target: subscribers as u64,
        mx: Mutex::new(0),
        cv: Condvar::new(),
    });
    // Keep subscriber communicators alive for the whole case.
    let mut subs = Vec::new();
    for _ in 0..subscribers {
        let comm = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
        let gate2 = Arc::clone(&gate);
        let filter = if filtered {
            // Half the traffic is filtered out subscriber-side.
            BroadcastFilter::all().subject("wanted.*")
        } else {
            BroadcastFilter::all()
        };
        comm.add_broadcast_subscriber(
            filter,
            Box::new(move |_msg| {
                let n = gate2.count.fetch_add(1, Ordering::Relaxed) + 1;
                if n % gate2.target == 0 {
                    let mut generation = gate2.mx.lock().unwrap();
                    *generation += 1;
                    gate2.cv.notify_all();
                }
            }),
        )
        .unwrap();
        subs.push(comm);
    }

    let payload = Value::map([("data", Value::Bytes(vec![0xAB; payload_bytes]))]);
    let hist = kiwi::metrics::Histogram::new();
    let bytes_out_before =
        broker.broker().metrics().counter("broker.bytes_out_total").get();
    let t_all = Instant::now();
    for _ in 0..rounds {
        let generation_before = *gate.mx.lock().unwrap();
        let t0 = Instant::now();
        if filtered {
            // One dropped message + one wanted message per round.
            sender.broadcast_send(payload.clone(), None, Some("noise.x")).unwrap();
        }
        sender.broadcast_send(payload.clone(), None, Some("wanted.x")).unwrap();
        let mut generation = gate.mx.lock().unwrap();
        while *generation <= generation_before {
            let (g, timeout) =
                gate.cv.wait_timeout(generation, Duration::from_secs(30)).unwrap();
            generation = g;
            assert!(!timeout.timed_out(), "fan-out did not complete");
        }
        hist.record_duration(t0.elapsed());
    }
    let elapsed = t_all.elapsed();
    let egress = broker.broker().metrics().counter("broker.bytes_out_total").get()
        - bytes_out_before;
    let msgs = rounds * subscribers;
    CaseResult {
        p50: Duration::from_nanos(hist.quantile(0.5)),
        p99: Duration::from_nanos(hist.quantile(0.99)),
        deliveries_per_s: msgs as f64 / elapsed.as_secs_f64(),
        egress_mb_s: egress as f64 / 1e6 / elapsed.as_secs_f64(),
    }
}

fn main() {
    let rounds = if smoke() { 5 } else { 100 };
    let fan_counts: &[usize] = if smoke() { &[1, 4] } else { &[1, 4, 16, 64, 256] };

    let mut table = Table::new(
        "E3 broadcast fan-out (inproc broker)",
        &[
            "subscribers",
            "payload",
            "filtered",
            "p50 all-received",
            "p99",
            "deliveries/s",
            "egress MB/s",
        ],
    );
    for &n in fan_counts {
        for &filtered in &[false, true] {
            let r = run_case(n, 64, filtered, rounds);
            table.row(&[
                n.to_string(),
                "64B".into(),
                filtered.to_string(),
                fmt_dur(r.p50),
                fmt_dur(r.p99),
                format!("{:.0}", r.deliveries_per_s),
                format!("{:.1}", r.egress_mb_s),
            ]);
        }
    }
    // Payload sweep: the encode-once win grows with payload size (the
    // per-subscriber copy used to be a re-encode; now it's a refcount).
    let sweep: &[(usize, usize, &str)] = if smoke() {
        &[(4, 64 * 1024, "64KiB")]
    } else {
        &[
            (4, 64 * 1024, "64KiB"),
            (64, 64 * 1024, "64KiB"),
            (4, 1024 * 1024, "1MiB"),
            (64, 1024 * 1024, "1MiB"),
        ]
    };
    let sweep_rounds = if smoke() { 5 } else { 50 };
    for &(n, size, label) in sweep {
        let r = run_case(n, size, false, sweep_rounds);
        table.row(&[
            n.to_string(),
            label.into(),
            "false".into(),
            fmt_dur(r.p50),
            fmt_dur(r.p99),
            format!("{:.0}", r.deliveries_per_s),
            format!("{:.1}", r.egress_mb_s),
        ]);
    }
    table.emit();
    println!("expected shape: all-received latency grows ~linearly with\n\
              subscribers (one queue copy each, but all copies share one\n\
              encoded buffer); large payloads cost one encode + N decodes,\n\
              so egress MB/s holds up where the old path re-encoded per\n\
              recipient. Filtering costs one extra dropped delivery per\n\
              subscriber, not a broker-side scan.");
}
