//! E2 — RPC round-trip latency (paper §I.B: control of live processes).
//!
//! Latency distribution of `rpc_send(..).wait()` over the in-process link
//! and over real TCP loopback, at 1–8 concurrent callers.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kiwi::benchutil::{runner::fmt_dur, Table};
use kiwi::broker::{BrokerHandle, BrokerServer, InprocBroker};
use kiwi::communicator::{Communicator, RmqCommunicator, RmqConfig};
use kiwi::metrics::Histogram;
use kiwi::transport::connect_tcp;
use kiwi::wire::Value;

const CALLS_PER_CLIENT: usize = 500;

fn bench_clients(
    make_comm: &dyn Fn() -> Arc<RmqCommunicator>,
    clients: usize,
) -> (Histogram, f64) {
    let server = make_comm();
    server
        .add_rpc_subscriber("echo", Box::new(|v| Ok(v)))
        .unwrap();
    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let comm = make_comm();
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                for i in 0..CALLS_PER_CLIENT {
                    let t = Instant::now();
                    comm.rpc_send("echo", Value::I64(i as i64))
                        .unwrap()
                        .wait(Duration::from_secs(30))
                        .unwrap();
                    hist.record_duration(t.elapsed());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = clients * CALLS_PER_CLIENT;
    let thpt = total as f64 / t0.elapsed().as_secs_f64();
    drop(server);
    (Arc::try_unwrap(hist).unwrap_or_else(|_| panic!()), thpt)
}

fn main() {
    let mut table = Table::new(
        "E2 RPC round-trip latency",
        &["transport", "clients", "p50", "p99", "mean", "calls/s"],
    );

    // In-process link.
    let inproc = InprocBroker::new();
    for &clients in &[1usize, 2, 4, 8] {
        let broker = inproc.clone();
        let make = move || {
            Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap())
        };
        let (hist, thpt) = bench_clients(&make, clients);
        table.row(&[
            "inproc".into(),
            clients.to_string(),
            fmt_dur(Duration::from_nanos(hist.quantile(0.5))),
            fmt_dur(Duration::from_nanos(hist.quantile(0.99))),
            fmt_dur(Duration::from_nanos(hist.mean() as u64)),
            format!("{thpt:.0}"),
        ]);
    }

    // TCP loopback.
    let server = BrokerServer::start(BrokerHandle::new(), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    for &clients in &[1usize, 2, 4, 8] {
        let make = move || {
            Arc::new(
                RmqCommunicator::connect(
                    Arc::new(connect_tcp(addr).unwrap()),
                    RmqConfig::default(),
                )
                .unwrap(),
            )
        };
        let (hist, thpt) = bench_clients(&make, clients);
        table.row(&[
            "tcp".into(),
            clients.to_string(),
            fmt_dur(Duration::from_nanos(hist.quantile(0.5))),
            fmt_dur(Duration::from_nanos(hist.quantile(0.99))),
            fmt_dur(Duration::from_nanos(hist.mean() as u64)),
            format!("{thpt:.0}"),
        ]);
    }
    server.shutdown();
    table.emit();
    println!("expected shape: inproc ~10x lower latency than TCP loopback;\n\
              p99 grows mildly with concurrency (single broker lock).");
}
