//! §Perf — topic routing: the trie index + interned route cache vs the
//! seed linear-scan matcher (every binding through the `topic_matches`
//! DP table).
//!
//! Sweeps bindings ∈ {16, 256, 4096} × key depth ∈ {3, 6} × mode:
//!
//! * `seed-linear`   — the seed's routing: scan all bindings with the
//!   retained reference DP matcher, clone matches into `Vec<String>`.
//! * `trie`          — trie-indexed resolution, cache disabled
//!   (`route_cache_cap = 0`): the cache-miss resolution cost.
//! * `cache-miss`    — trie resolution + cache fill, each key seen once.
//! * `cache-hit`     — warm cache: one map probe + one atomic generation
//!   load + a refcount bump; zero allocations.
//!
//! Emits the usual table + CSV, a consolidated machine-readable
//! `target/bench-results/BENCH_routing.json` (the perf-trajectory
//! artifact the CI smoke job uploads), and the
//! `broker.route_cache_hits_total` / `route_cache_misses_total` counters.
//!
//! `KIWI_BENCH_SMOKE=1` shrinks the measurement budget so CI can run this
//! as a regression tripwire rather than a measurement.

use std::collections::BTreeSet;
use std::time::Duration;

use kiwi::benchutil::{bench, bench_n, BenchResult, Table};
use kiwi::broker::exchange::topic_matches;
use kiwi::broker::protocol::ExchangeKind;
use kiwi::broker::router::Router;
use kiwi::metrics::Registry;
use kiwi::wire::{json, Value};

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// The seed matcher: a flat binding list scanned end to end per route,
/// results converted to owned `String`s exactly like the seed
/// `Router::route` did.
struct LinearMatcher {
    bindings: Vec<(String, String)>,
}

impl LinearMatcher {
    fn route(&self, key: &str) -> Vec<String> {
        let mut seen = BTreeSet::new();
        self.bindings
            .iter()
            .filter(|(pat, q)| topic_matches(pat, key) && seen.insert(q.as_str()))
            .map(|(_, q)| q.clone())
            .collect()
    }
}

/// AiiDA-shaped workload: mostly process-specific literal patterns
/// (`proc.{i}.terminated`-style, padded to `depth` words), plus a few
/// wildcard audit subscriptions that match broad key classes.
fn make_bindings(n: usize, depth: usize) -> Vec<(String, String)> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut words: Vec<String> = vec!["proc".into(), i.to_string(), "done".into()];
        while words.len() < depth {
            words.push(format!("s{}", words.len()));
        }
        let queue = format!("q{i}");
        if i % 64 == 63 {
            // Wildcard audit subscription: one word replaced by '*'.
            words[1] = "*".into();
        } else if i % 256 == 129 {
            // Firehose subscription.
            words = vec!["proc".into(), "#".into()];
        }
        out.push((words.join("."), queue));
    }
    out
}

fn make_key(i: usize, n: usize, depth: usize) -> String {
    let mut words: Vec<String> = vec!["proc".into(), (i % n).to_string(), "done".into()];
    while words.len() < depth {
        words.push(format!("s{}", words.len()));
    }
    words.join(".")
}

fn build_router(cap: usize, bindings: &[(String, String)], registry: &Registry) -> Router {
    let router = Router::with_cache(
        cap,
        registry.counter("broker.route_cache_hits_total"),
        registry.counter("broker.route_cache_misses_total"),
    );
    router.declare_exchange("bench", ExchangeKind::Topic).unwrap();
    for (pat, q) in bindings {
        router.register_queue(q);
        router.bind("bench", q, pat).unwrap();
    }
    router
}

struct Case {
    bindings: usize,
    depth: usize,
    mode: &'static str,
    result: BenchResult,
    speedup: f64,
}

fn main() {
    let target = if smoke() { Duration::from_millis(15) } else { Duration::from_millis(250) };
    let mut table = Table::new(
        "Perf: topic routing (trie + route cache vs seed linear scan)",
        &["bindings", "depth", "mode", "mean", "routes/s", "speedup vs seed"],
    );
    let mut cases: Vec<Case> = Vec::new();
    let registry = Registry::new();

    for &nbind in &[16usize, 256, 4096] {
        for &depth in &[3usize, 6] {
            let bindings = make_bindings(nbind, depth);
            let linear = LinearMatcher { bindings: bindings.clone() };
            // Pre-built key pool so every mode measures routing, not
            // key construction.
            let keys: Vec<String> =
                (0..1024).map(|i| make_key(i, nbind, depth)).collect();

            // Baseline: the seed linear scan.
            let mut i = 0usize;
            let seed_result = bench(&format!("seed b{nbind} d{depth}"), target, || {
                let key = &keys[i % keys.len()];
                i += 1;
                std::hint::black_box(linear.route(key));
            });
            let seed_ns = seed_result.mean().as_nanos().max(1) as f64;

            // Trie, cache disabled: pure resolution cost.
            let router = build_router(0, &bindings, &registry);
            let mut i = 0usize;
            let trie_result = bench(&format!("trie b{nbind} d{depth}"), target, || {
                let key = &keys[i % keys.len()];
                i += 1;
                std::hint::black_box(router.route("bench", key).unwrap());
            });

            // Cache miss: every key seen exactly once (fill path). A
            // fixed iteration count bounded by the key list keeps each
            // measured route a genuine miss; keys are pre-built so the
            // measurement is the route itself, as in the other modes.
            let miss_iters: u64 = if smoke() { 2_000 } else { 100_000 };
            let miss_keys: Vec<String> =
                (0..miss_iters).map(|i| format!("proc.m{i}.done")).collect();
            let router = build_router(usize::MAX, &bindings, &registry);
            let mut i = 0usize;
            let miss_result = bench_n(&format!("miss b{nbind} d{depth}"), 0, miss_iters, || {
                let key = &miss_keys[i];
                i += 1;
                std::hint::black_box(router.route("bench", key).unwrap());
            });

            // Cache hit: 16 hot keys, warm.
            let router = build_router(4096, &bindings, &registry);
            let hot_keys: Vec<String> =
                (0..16).map(|i| make_key(i, nbind, depth)).collect();
            for key in &hot_keys {
                router.route("bench", key).unwrap();
            }
            let mut i = 0usize;
            let hit_result = bench(&format!("hit b{nbind} d{depth}"), target, || {
                let key = &hot_keys[i % hot_keys.len()];
                i += 1;
                std::hint::black_box(router.route("bench", key).unwrap());
            });

            for (mode, result) in [
                ("seed-linear", seed_result),
                ("trie", trie_result),
                ("cache-miss", miss_result),
                ("cache-hit", hit_result),
            ] {
                let speedup = seed_ns / result.mean().as_nanos().max(1) as f64;
                table.row(&[
                    nbind.to_string(),
                    depth.to_string(),
                    mode.into(),
                    format!("{:.2?}", result.mean()),
                    format!("{:.0}", result.throughput()),
                    format!("{speedup:.1}x"),
                ]);
                cases.push(Case { bindings: nbind, depth, mode, result, speedup });
            }
        }
    }
    table.emit();

    let hits = registry.counter("broker.route_cache_hits_total").get();
    let misses = registry.counter("broker.route_cache_misses_total").get();
    println!(
        "route cache counters across the run: broker.route_cache_hits_total={hits} \
         broker.route_cache_misses_total={misses}"
    );

    // Consolidated machine-readable summary: the perf-trajectory record.
    let json_cases: Vec<Value> = cases
        .iter()
        .map(|c| {
            Value::map([
                ("bindings", Value::from(c.bindings)),
                ("depth", Value::from(c.depth)),
                ("mode", Value::from(c.mode)),
                ("mean_ns", Value::from(c.result.mean().as_nanos() as u64)),
                ("p99_ns", Value::from(c.result.p99().as_nanos() as u64)),
                ("routes_per_s", Value::from(c.result.throughput())),
                ("speedup_vs_seed", Value::from(c.speedup)),
            ])
        })
        .collect();
    let summary = Value::map([
        ("bench", Value::from("topic_routing")),
        ("smoke", Value::from(smoke())),
        ("cases", Value::List(json_cases)),
        (
            "route_cache",
            Value::map([("hits", Value::from(hits)), ("misses", Value::from(misses))]),
        ),
    ]);
    let path = std::path::Path::new("target/bench-results/BENCH_routing.json");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(path, json::to_string(&summary)) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    // The acceptance gate this bench exists to demonstrate.
    for c in cases.iter().filter(|c| c.bindings == 4096 && c.mode == "cache-hit") {
        println!(
            "gate: cache-hit at 4096 bindings depth {} is {:.0}x the seed linear scan \
             (target ≥ 10x)",
            c.depth, c.speedup
        );
    }
    println!(
        "\nexpected shape: seed-linear degrades linearly with binding count;\n\
         trie resolution tracks key depth instead, and cache-hit is flat —\n\
         a hash probe + atomic load + refcount bump, independent of both."
    );
}
