//! E4 — durability & atomicity (paper §I: the broker "takes responsibility
//! for guaranteeing the durability and atomicity of messages").
//!
//! Two questions:
//!
//! * **E4a — policy cost**: what does each sync policy cost a single
//!   publisher, transient vs durable?
//! * **E4b — durable scaling**: does durable-publish throughput scale
//!   with publisher threads? The single-mutex `WalPersister` baseline
//!   serialises every durable publish (fsync held under the lock); the
//!   `SegmentedWal` shards the log per queue shard and pipelines group
//!   commit, so threads on different queues should scale until the disk
//!   itself saturates.
//!
//! Each `SegmentedWal` row also reports the WAL observability counters
//! (`appends`/`fsyncs`/`bytes`/`batch_max`) so the CSV shows *why* a
//! configuration is fast (group-commit batching) or slow (fsync per
//! publish). `KIWI_BENCH_SMOKE=1` shrinks the matrix for CI;
//! `KIWI_BENCH_RECORD=1` appends the run to `../BENCH_durability.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::core::{BrokerConfig, BrokerHandle};
use kiwi::broker::persistence::{
    NoopPersister, PersistBackend, RecoveredState, SegmentedWal, SyncPolicy, WalPersister,
};
use kiwi::broker::protocol::{ClientRequest, MessageProps, QueueOptions};
use kiwi::wire::{json, Value};

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Publish `per_thread` 512-byte durable messages from each of `threads`
/// publishers, one queue per thread (queues hash across shards and WAL
/// segments), then sync. Returns the wall time for the whole batch.
fn publish_threads(
    broker: &BrokerHandle,
    durable: bool,
    threads: usize,
    per_thread: usize,
) -> Duration {
    // Encoded once; every publish (and WAL record) shares this buffer.
    let body = kiwi::wire::Bytes::encode(&Value::map([("data", Value::Bytes(vec![7u8; 512]))]));
    for t in 0..threads {
        let (tx, _rx) = std::sync::mpsc::channel();
        let conn = broker.connect(&format!("bench-{t}"), 0, tx);
        broker
            .handle(
                conn,
                &ClientRequest::QueueDeclare {
                    queue: format!("q{t}"),
                    options: QueueOptions { durable, ..Default::default() },
                },
            )
            .unwrap();
        broker.disconnect(conn);
    }
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..threads {
            let body = body.clone();
            scope.spawn(move || {
                let (tx, _rx) = std::sync::mpsc::channel();
                let conn = broker.connect(&format!("bench-pub-{t}"), 0, tx);
                for _ in 0..per_thread {
                    broker
                        .handle(
                            conn,
                            &ClientRequest::Publish {
                                exchange: "".into(),
                                routing_key: format!("q{t}"),
                                body: body.clone(),
                                props: MessageProps { persistent: durable, ..Default::default() }
                                    .into(),
                                mandatory: true,
                            },
                        )
                        .unwrap();
                }
            });
        }
    });
    broker.sync().unwrap();
    t0.elapsed()
}

fn bench_root(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("kiwi-bench-wal-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(tag)
}

fn policy_tag(policy: SyncPolicy) -> &'static str {
    match policy {
        SyncPolicy::Os => "os",
        SyncPolicy::EveryN(_) => "every-64",
        SyncPolicy::Always => "always",
    }
}

struct RunOut {
    wall: Duration,
    msgs_per_sec: f64,
    /// (appends, fsyncs, bytes, batch_max) — segmented backend only.
    counters: Option<(u64, u64, u64, u64)>,
}

/// One durable matrix cell. `segmented = false` is the baseline: the old
/// single-file `WalPersister` behind the compatibility mutex.
fn run_case(segmented: bool, policy: SyncPolicy, threads: usize, per_thread: usize) -> RunOut {
    let tag = format!(
        "{}-{}-t{threads}",
        if segmented { "seg" } else { "mutex" },
        policy_tag(policy)
    );
    let path = bench_root(&tag);
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir_all(&path).ok();
    let config = BrokerConfig::default();
    let (broker, wal) = if segmented {
        let (wal, rec) =
            SegmentedWal::open(&path, config.shards, policy, Duration::from_micros(500)).unwrap();
        let wal = Arc::new(wal);
        let backend: Arc<dyn PersistBackend> = Arc::clone(&wal);
        (BrokerHandle::with_backend(backend, rec, config), Some(wal))
    } else {
        let (wal, rec) = WalPersister::open(&path, policy).unwrap();
        (BrokerHandle::with_config(Box::new(wal), rec, config), None)
    };
    let wall = publish_threads(&broker, true, threads, per_thread);
    let total = (threads * per_thread) as f64;
    RunOut {
        wall,
        msgs_per_sec: total / wall.as_secs_f64(),
        counters: wal.map(|w| {
            let s = w.stats();
            (s.appends.get(), s.fsyncs.get(), s.bytes.get(), s.batch_max.get())
        }),
    }
}

fn main() {
    let smoke = smoke();
    // Always is fsync-bound; fewer messages keep its rows affordable.
    let n_fast: usize = if smoke { 200 } else { 2_000 };
    let n_always: usize = if smoke { 40 } else { 250 };
    let thread_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let policies = [SyncPolicy::Os, SyncPolicy::EveryN(64), SyncPolicy::Always];

    // E4a: single-publisher policy cost, transient as the reference.
    let transient = {
        let broker =
            BrokerHandle::with_persister(Box::new(NoopPersister), RecoveredState::default());
        publish_threads(&broker, false, 1, n_fast)
    };
    let mut e4a = Table::new(
        "E4a durability: single-publisher policy cost (512B msgs)",
        &["mode", "msgs", "wall", "msgs/s", "vs transient"],
    );
    e4a.row(&[
        "transient".into(),
        n_fast.to_string(),
        format!("{transient:.2?}"),
        format!("{:.0}", n_fast as f64 / transient.as_secs_f64()),
        "1.0x".into(),
    ]);
    for policy in policies {
        let n = if matches!(policy, SyncPolicy::Always) { n_always } else { n_fast };
        let out = run_case(true, policy, 1, n);
        let per_msg_transient = transient.as_secs_f64() / n_fast as f64;
        let per_msg = out.wall.as_secs_f64() / n as f64;
        e4a.row(&[
            format!("seg wal {}", policy_tag(policy)),
            n.to_string(),
            format!("{:.2?}", out.wall),
            format!("{:.0}", out.msgs_per_sec),
            format!("{:.1}x", per_msg / per_msg_transient),
        ]);
    }
    e4a.emit();

    // E4b: the scaling matrix — threads x policy x backend.
    let mut e4b = Table::new(
        "E4b durability: durable-publish scaling (per-thread queues)",
        &[
            "backend", "policy", "threads", "msgs", "wall", "msgs/s", "appends", "fsyncs",
            "bytes", "batch_max",
        ],
    );
    let mut curve: Vec<(String, f64)> = Vec::new();
    for &segmented in &[false, true] {
        for policy in policies {
            let per_thread = if matches!(policy, SyncPolicy::Always) { n_always } else { n_fast };
            for &threads in thread_counts {
                let out = run_case(segmented, policy, threads, per_thread);
                let (appends, fsyncs, bytes, batch_max) = match out.counters {
                    Some((a, f, b, m)) => {
                        (a.to_string(), f.to_string(), b.to_string(), m.to_string())
                    }
                    None => ("-".into(), "-".into(), "-".into(), "-".into()),
                };
                let backend = if segmented { "segmented" } else { "mutex" };
                e4b.row(&[
                    backend.into(),
                    policy_tag(policy).into(),
                    threads.to_string(),
                    (threads * per_thread).to_string(),
                    format!("{:.2?}", out.wall),
                    format!("{:.0}", out.msgs_per_sec),
                    appends,
                    fsyncs,
                    bytes,
                    batch_max,
                ]);
                curve.push((
                    format!("{backend}_{}_t{threads}", policy_tag(policy).replace('-', "")),
                    out.msgs_per_sec,
                ));
            }
        }
    }
    e4b.emit();

    let rate = |key: &str| curve.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(0.0);
    let gate_threads = *thread_counts.last().unwrap();
    let seg_rate = rate(&format!("segmented_every64_t{gate_threads}"));
    let speedup_every64 = seg_rate / rate(&format!("mutex_every64_t{gate_threads}"));
    let os_ratio = rate("segmented_os_t1") / rate("mutex_os_t1");
    // Acceptance tripwires (printed, not asserted: CI hardware varies, the
    // series file is the judge): every-64 at max threads should be >=2x
    // the single-mutex baseline, and the os path must not regress.
    println!(
        "gate: every-64 x{gate_threads} segmented/mutex speedup = {speedup_every64:.2}x \
         (want >= 2x)"
    );
    println!("gate: os x1 segmented/mutex ratio = {os_ratio:.2} (want ~1x, no regression)");

    // Recovery: reopen the segmented every-64 log from the widest run and
    // verify nothing durable was lost, timing the (parallel) replay.
    let expect = gate_threads * n_fast;
    let path = bench_root(&format!("seg-every-64-t{gate_threads}"));
    let t0 = Instant::now();
    let recovered = kiwi::broker::persistence::replay_dir(&path).unwrap();
    let replay = t0.elapsed();
    let mut e4c = Table::new("E4c recovery after restart", &["metric", "value"]);
    e4c.row(&["messages recovered".into(), recovered.message_count().to_string()]);
    e4c.row(&["expected".into(), expect.to_string()]);
    e4c.row(&["replay time".into(), format!("{replay:.2?}")]);
    e4c.emit();
    assert_eq!(recovered.message_count(), expect, "durable messages must survive restart");

    let mut run_fields = vec![
        ("bench", Value::from("durability")),
        ("smoke", Value::from(smoke)),
        ("msgs_fast", Value::from(n_fast)),
        ("msgs_always", Value::from(n_always)),
        ("speedup_every64_max_threads", Value::F64(speedup_every64)),
        ("os_ratio_t1", Value::F64(os_ratio)),
        ("recovered", Value::from(recovered.message_count())),
        ("replay_ns", Value::from(replay.as_nanos() as u64)),
    ];
    for (k, v) in &curve {
        run_fields.push((k.as_str(), Value::F64(*v)));
    }
    let run = Value::map(run_fields);
    let path = std::path::Path::new("target/bench-results/BENCH_durability.json");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(path, json::to_string(&run)) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    // Tracked trajectory series at the repo root: append this run when
    // recording is requested (benches run from rust/, the series lives
    // one level up).
    if std::env::var("KIWI_BENCH_RECORD").is_ok_and(|v| !v.is_empty() && v != "0") {
        let series_path = std::path::Path::new("../BENCH_durability.json");
        let mut series = std::fs::read_to_string(series_path)
            .ok()
            .and_then(|t| json::from_str(&t).ok())
            .unwrap_or_else(|| {
                Value::map([
                    ("bench", Value::from("durability")),
                    ("runs", Value::List(Vec::new())),
                ])
            });
        if let Value::Map(m) = &mut series {
            let runs = m.entry("runs".to_string()).or_insert_with(|| Value::List(Vec::new()));
            if let Value::List(list) = runs {
                list.push(run);
            }
        }
        match std::fs::write(series_path, json::to_string_pretty(&series)) {
            Ok(()) => println!("recorded run into {}", series_path.display()),
            Err(e) => eprintln!("warning: could not record series: {e}"),
        }
    }
}
