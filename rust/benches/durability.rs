//! E4 — durability & atomicity (paper §I: the broker "takes responsibility
//! for guaranteeing the durability and atomicity of messages").
//!
//! Cost of the write-ahead log: publish throughput for transient vs
//! durable queues under each sync policy, plus recovery time and
//! completeness after a broker restart.

use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::core::BrokerHandle;
use kiwi::broker::persistence::{NoopPersister, RecoveredState, SyncPolicy, WalPersister};
use kiwi::broker::protocol::{ClientRequest, MessageProps, QueueOptions};
use kiwi::wire::Value;

const MSGS: usize = 2_000;

fn publish_n(broker: &BrokerHandle, durable: bool, n: usize) -> Duration {
    let (tx, _rx) = std::sync::mpsc::channel();
    let conn = broker.connect("bench", 0, tx);
    broker
        .handle(
            conn,
            &ClientRequest::QueueDeclare {
                queue: "q".into(),
                options: QueueOptions { durable, ..Default::default() },
            },
        )
        .unwrap();
    // Encoded once; every publish (and WAL record) shares this buffer.
    let body = kiwi::wire::Bytes::encode(&Value::map([("data", Value::Bytes(vec![7u8; 512]))]));
    let t0 = Instant::now();
    for _ in 0..n {
        broker
            .handle(
                conn,
                &ClientRequest::Publish {
                    exchange: "".into(),
                    routing_key: "q".into(),
                    body: body.clone(),
                    props: MessageProps { persistent: durable, ..Default::default() }.into(),
                    mandatory: true,
                },
            )
            .unwrap();
    }
    broker.sync().unwrap();
    t0.elapsed()
}

fn wal_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("kiwi-bench-wal-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.wal"))
}

fn main() {
    let mut table = Table::new(
        "E4 durability: publish cost (2000 x 512B msgs)",
        &["mode", "wall", "msgs/s", "vs transient"],
    );
    let transient = {
        let broker = BrokerHandle::with_persister(
            Box::new(NoopPersister),
            RecoveredState::default(),
        );
        publish_n(&broker, false, MSGS)
    };
    table.row(&[
        "transient".into(),
        format!("{transient:.2?}"),
        format!("{:.0}", MSGS as f64 / transient.as_secs_f64()),
        "1.0x".into(),
    ]);
    for (label, policy) in [
        ("wal os-sync", SyncPolicy::Os),
        ("wal every-64", SyncPolicy::EveryN(64)),
        ("wal always", SyncPolicy::Always),
    ] {
        let path = wal_dir(label);
        std::fs::remove_file(&path).ok();
        let (wal, rec) = WalPersister::open(&path, policy).unwrap();
        let broker = BrokerHandle::with_persister(Box::new(wal), rec);
        let wall = publish_n(&broker, true, MSGS);
        table.row(&[
            label.into(),
            format!("{wall:.2?}"),
            format!("{:.0}", MSGS as f64 / wall.as_secs_f64()),
            format!("{:.1}x", wall.as_secs_f64() / transient.as_secs_f64()),
        ]);
    }
    table.emit();

    // Recovery: restart the broker from the every-64 WAL and verify that
    // all messages survive, timing the replay.
    let path = wal_dir("wal every-64");
    let t0 = Instant::now();
    let (_wal, recovered) = WalPersister::open(&path, SyncPolicy::EveryN(64)).unwrap();
    let replay = t0.elapsed();
    let mut recovery = Table::new(
        "E4b recovery after restart",
        &["metric", "value"],
    );
    recovery.row(&["messages recovered".into(), recovered.message_count().to_string()]);
    recovery.row(&["expected".into(), MSGS.to_string()]);
    recovery.row(&["replay time".into(), format!("{replay:.2?}")]);
    recovery.emit();
    assert_eq!(recovered.message_count(), MSGS, "durable messages must survive restart");
    println!("expected shape: os-sync ~ transient; every-64 a small constant\n\
              factor; fsync-always dominated by disk flushes. Recovery is\n\
              linear in live messages and loses nothing.");
}
