//! E9 — stream replay fan-out (the append-only log exchange).
//!
//! One durable stream, N single-member consumer groups all replaying the
//! whole log from offset 0 concurrently. The claims this bench pins:
//!
//! * **Fan-out MB/s**: delivery is a refcount bump on the entry's shared
//!   `Bytes` (plus a bounded page-in from the segment file once the entry
//!   leaves the resident window), so aggregate replay bandwidth scales
//!   with reader count instead of being throttled by per-reader copies.
//! * **Flat broker RSS**: replaying the log 100× must not hold 100 copies
//!   (or even one full copy) in memory — resident stream bytes are
//!   bounded by the resident window and RSS growth stays within a budget
//!   independent of `readers × log_bytes`.
//! * **Zero loss, in order**: every group sees every offset exactly once,
//!   in offset order (single member, single partition).
//!
//! `KIWI_BENCH_SMOKE=1` shrinks readers and the log so CI can run this as
//! a stream-path regression tripwire; `KIWI_BENCH_RECORD=1` appends the
//! run to `../BENCH_stream.json`.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::core::{process_rss_bytes, BrokerConfig, BrokerHandle};
use kiwi::broker::persistence::{PersistBackend, SegmentedWal, SyncPolicy};
use kiwi::broker::protocol::{ClientRequest, MessageProps, QueueOptions, ServerMsg};
use kiwi::wire::{json, Bytes, Value};

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

const MIB: u64 = 1024 * 1024;
const BODY_BYTES: usize = 1024;

fn wal_broker(config: BrokerConfig) -> (BrokerHandle, std::path::PathBuf) {
    let dir =
        std::env::temp_dir().join(format!("kiwi-bench-stream-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (wal, rec) =
        SegmentedWal::open(&dir, config.shards, SyncPolicy::Os, Duration::from_micros(500))
            .unwrap();
    let backend: Arc<dyn PersistBackend> = Arc::new(wal);
    (BrokerHandle::with_backend(backend, rec, config), dir)
}

/// One reader: attach a fresh single-member group at offset 0, drain the
/// whole log acking as it goes, and return how many entries arrived in
/// strict offset order (must be all of them).
fn run_reader(broker: &BrokerHandle, idx: usize, entries: u64) -> u64 {
    let (tx, rx) = channel();
    let conn = broker.connect(&format!("reader-{idx}"), 0, tx);
    broker
        .handle(
            conn,
            &ClientRequest::StreamConsume {
                queue: "firehose".into(),
                consumer_tag: format!("c{idx}"),
                group: format!("g{idx}"),
                prefetch: 256,
                offset: Some(0),
            },
        )
        .unwrap();
    let mut expected = 0u64;
    while expected < entries {
        let msg = match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(m) => m,
            Err(_) => break,
        };
        let ds = match msg {
            ServerMsg::Deliver(d) => vec![d],
            ServerMsg::DeliverBatch(ds) => ds,
            _ => continue,
        };
        for d in ds {
            if d.offset != Some(expected) {
                break;
            }
            expected += 1;
            broker.handle(conn, &ClientRequest::Ack { delivery_tag: d.delivery_tag }).unwrap();
        }
    }
    broker.disconnect(conn);
    expected
}

fn main() {
    let smoke = smoke();
    let readers: usize = if smoke { 10 } else { 100 };
    let entries: u64 = if smoke { 2_000 } else { 20_000 };
    let log_bytes = entries * BODY_BYTES as u64;
    // The flatness claim: the budget covers the resident window, WAL and
    // segment write buffers, per-reader channel/prefetch slack and
    // allocator noise — nothing proportional to readers × log size.
    let rss_budget: u64 = 192 * MIB + (readers as u64 * 256 * BODY_BYTES as u64 * 2);

    let (broker, dir) = wal_broker(BrokerConfig::default());
    {
        let (tx, _rx) = channel();
        let conn = broker.connect("declare", 0, tx);
        broker
            .handle(
                conn,
                &ClientRequest::QueueDeclare {
                    queue: "firehose".into(),
                    options: QueueOptions {
                        stream: true,
                        partitions: 1,
                        durable: true,
                        ..Default::default()
                    },
                },
            )
            .unwrap();
        broker.disconnect(conn);
    }

    // Append the log.
    let body = Bytes::encode(&Value::map([("data", Value::Bytes(vec![0x5A; BODY_BYTES]))]));
    let (tx, _prx) = channel();
    let publisher = broker.connect("publisher", 0, tx);
    let t_pub = Instant::now();
    for _ in 0..entries {
        broker
            .handle(
                publisher,
                &ClientRequest::Publish {
                    exchange: "".into(),
                    routing_key: "firehose".into(),
                    body: body.clone(),
                    props: MessageProps { persistent: true, ..Default::default() }.into(),
                    mandatory: true,
                },
            )
            .unwrap();
    }
    let publish_wall = t_pub.elapsed();
    broker.disconnect(publisher);

    // Replay fan-out: all readers at once, each its own group from 0.
    let rss_before = process_rss_bytes().unwrap_or(0);
    let broker = Arc::new(broker);
    let t_fan = Instant::now();
    let handles: Vec<_> = (0..readers)
        .map(|i| {
            let broker = Arc::clone(&broker);
            std::thread::spawn(move || run_reader(&broker, i, entries))
        })
        .collect();
    let mut drained_total = 0u64;
    for h in handles {
        drained_total += h.join().unwrap();
    }
    let fan_wall = t_fan.elapsed();
    let rss_peak = process_rss_bytes().unwrap_or(0);
    let rss_growth = rss_peak.saturating_sub(rss_before);
    let resident = broker.stream_resident_bytes("firehose").unwrap_or(0);
    let disk = broker.stream_disk_bytes("firehose").unwrap_or(0);

    let fanned_bytes = readers as u64 * log_bytes;
    let fan_mb_s = fanned_bytes as f64 / 1e6 / fan_wall.as_secs_f64().max(1e-9);
    let deliveries_per_s =
        (readers as u64 * entries) as f64 / fan_wall.as_secs_f64().max(1e-9);

    let mut table = Table::new(
        "E9 stream replay fan-out (durable stream, 1KiB entries)",
        &["metric", "value"],
    );
    table.row(&["readers (groups)".into(), readers.to_string()]);
    table.row(&["log entries".into(), entries.to_string()]);
    table.row(&["log bytes".into(), format!("{} MiB", log_bytes / MIB)]);
    table.row(&["append wall".into(), format!("{publish_wall:.2?}")]);
    table.row(&[
        "append MB/s".into(),
        format!("{:.1}", log_bytes as f64 / 1e6 / publish_wall.as_secs_f64().max(1e-9)),
    ]);
    table.row(&["replay wall (all readers)".into(), format!("{fan_wall:.2?}")]);
    table.row(&["fan-out MB/s".into(), format!("{fan_mb_s:.1}")]);
    table.row(&["deliveries/s".into(), format!("{deliveries_per_s:.0}")]);
    table.row(&["stream resident bytes".into(), resident.to_string()]);
    table.row(&["stream disk bytes".into(), disk.to_string()]);
    table.row(&["rss before replay".into(), format!("{} MiB", rss_before / MIB)]);
    table.row(&["rss after replay".into(), format!("{} MiB", rss_peak / MIB)]);
    table.row(&["rss growth".into(), format!("{} MiB", rss_growth / MIB)]);
    table.row(&["rss budget".into(), format!("{} MiB", rss_budget / MIB)]);
    table.emit();

    assert_eq!(
        drained_total,
        readers as u64 * entries,
        "every group must replay the full log with zero loss"
    );
    assert!(disk >= log_bytes, "entry bodies must live in the segment files");
    if rss_before > 0 {
        assert!(
            rss_growth <= rss_budget,
            "RSS grew {rss_growth} bytes replaying the log {readers}x; budget {rss_budget}"
        );
    }
    println!(
        "expected shape: fan-out MB/s scales with reader count (refcounted\n\
         delivery, no per-reader copies) while RSS growth stays flat —\n\
         bounded by the resident window and per-reader prefetch, never by\n\
         readers x log size."
    );

    let run = Value::map([
        ("bench", Value::from("stream_fanout")),
        ("smoke", Value::from(smoke)),
        ("readers", Value::from(readers)),
        ("entries", Value::from(entries)),
        ("body_bytes", Value::from(BODY_BYTES)),
        ("append_mb_per_sec", {
            Value::F64(log_bytes as f64 / 1e6 / publish_wall.as_secs_f64().max(1e-9))
        }),
        ("fanout_mb_per_sec", Value::F64(fan_mb_s)),
        ("deliveries_per_sec", Value::F64(deliveries_per_s)),
        ("stream_resident_bytes", Value::from(resident)),
        ("stream_disk_bytes", Value::from(disk)),
        ("rss_growth_bytes", Value::from(rss_growth)),
        ("rss_budget_bytes", Value::from(rss_budget)),
    ]);
    let path = std::path::Path::new("target/bench-results/BENCH_stream.json");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(path, json::to_string(&run)) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    if std::env::var("KIWI_BENCH_RECORD").is_ok_and(|v| !v.is_empty() && v != "0") {
        let series_path = std::path::Path::new("../BENCH_stream.json");
        let mut series = std::fs::read_to_string(series_path)
            .ok()
            .and_then(|t| json::from_str(&t).ok())
            .unwrap_or_else(|| {
                Value::map([
                    ("bench", Value::from("stream_fanout")),
                    ("runs", Value::List(Vec::new())),
                ])
            });
        if let Value::Map(m) = &mut series {
            let runs = m.entry("runs".to_string()).or_insert_with(|| Value::List(Vec::new()));
            if let Value::List(list) = runs {
                list.push(run);
            }
        }
        match std::fs::write(series_path, json::to_string_pretty(&series)) {
            Ok(()) => println!("recorded run into {}", series_path.display()),
            Err(e) => eprintln!("warning: could not record series: {e}"),
        }
    }

    drop(broker);
    std::fs::remove_dir_all(&dir).ok();
}
