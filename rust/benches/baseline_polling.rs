//! E6 — event-based broker vs the "home-made polling" status quo the
//! paper calls out (§I). Same workload, two systems:
//!
//! * kiwi broker: event-driven task queue (this repo's contribution).
//! * PollingQueue: spool directory + rename-claim + poll loops.
//!
//! Reports task round-trip latency (sequential tasks — latency-bound) and
//! the polling tax: directory scans per completed task.

use std::time::{Duration, Instant};

use kiwi::baseline::{PollingQueue, PollingWorker};
use kiwi::benchutil::{runner::fmt_dur, Table};
use kiwi::broker::InprocBroker;
use kiwi::communicator::{Communicator, RmqCommunicator, RmqConfig};
use kiwi::metrics::Histogram;
use kiwi::wire::Value;

const TASKS: usize = 200;

fn bench_broker() -> (Histogram, f64) {
    let broker = InprocBroker::new();
    let client = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
    let worker = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
    worker
        .task_queue("bench.tasks", 1, Box::new(|t, ctx| ctx.complete(Ok(t))))
        .unwrap();
    let hist = Histogram::new();
    for i in 0..TASKS {
        let t0 = Instant::now();
        client
            .task_send("bench.tasks", Value::I64(i as i64))
            .unwrap()
            .wait(Duration::from_secs(30))
            .unwrap();
        hist.record_duration(t0.elapsed());
    }
    (hist, 0.0)
}

fn bench_polling(interval: Duration) -> (Histogram, f64) {
    let dir = std::env::temp_dir().join(format!(
        "kiwi-bench-spool-{}-{}",
        std::process::id(),
        interval.as_millis()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let q = PollingQueue::open(&dir).unwrap();
    let worker = PollingWorker::spawn(q.clone(), interval, |t| t.clone());
    let hist = Histogram::new();
    for i in 0..TASKS {
        let t0 = Instant::now();
        let id = q.submit(&Value::I64(i as i64)).unwrap();
        q.wait_result(&id, interval, Duration::from_secs(30)).unwrap();
        hist.record_duration(t0.elapsed());
    }
    let scans = worker.scans.load(std::sync::atomic::Ordering::Relaxed);
    worker.stop();
    std::fs::remove_dir_all(&dir).ok();
    (hist, scans as f64 / TASKS as f64)
}

fn main() {
    let mut table = Table::new(
        "E6 event-based broker vs polling baseline (200 sequential tasks)",
        &["system", "p50 rtt", "p99 rtt", "mean", "scans/task"],
    );
    let (hist, _) = bench_broker();
    let broker_p50 = hist.quantile(0.5);
    table.row(&[
        "kiwi broker (event)".into(),
        fmt_dur(Duration::from_nanos(hist.quantile(0.5))),
        fmt_dur(Duration::from_nanos(hist.quantile(0.99))),
        fmt_dur(Duration::from_nanos(hist.mean() as u64)),
        "-".into(),
    ]);
    for &ms in &[1u64, 10, 100] {
        let (hist, scans) = bench_polling(Duration::from_millis(ms));
        table.row(&[
            format!("polling @ {ms}ms"),
            fmt_dur(Duration::from_nanos(hist.quantile(0.5))),
            fmt_dur(Duration::from_nanos(hist.quantile(0.99))),
            fmt_dur(Duration::from_nanos(hist.mean() as u64)),
            format!("{scans:.1}"),
        ]);
        // The paper's claim, quantified: the event-based system beats the
        // polling floor (~interval/2 x 2 hops) by a growing factor.
        assert!(
            hist.quantile(0.5) > broker_p50,
            "polling @{ms}ms should be slower than event-based"
        );
    }
    table.emit();
    println!("expected shape: broker rtt is sub-ms and interval-independent;\n\
              polling rtt ~ poll interval (two poll hops: claim + result),\n\
              a >=10x gap at realistic intervals, plus wasted idle scans.");
}
