//! E7 — workflow-engine throughput and residency (paper §I.C: "scalable
//! from individual laptops ... workflows consisting of varying
//! durations").
//!
//! The claims this bench pins after the event-driven refactor:
//!
//! * **proc/s at campaign scale**: 100k flat processes through the full
//!   stack (launch task → daemon → scheduler → timer wait → checkpoint →
//!   terminal broadcast → reply) on a 4-worker scheduler.
//! * **O(workers) threads**: thread count during the campaign stays a
//!   small constant above baseline — never O(live processes).
//! * **Bounded residency**: with `max_resident` small, long-waiting
//!   processes park to their checkpoints and resume through the task
//!   queue; steady-state RSS is bounded by residency, not campaign size.
//! * **Checkpoint-store cost**: file vs memory store at equal shape.
//!
//! `KIWI_BENCH_SMOKE=1` shrinks the campaign so CI can run this as a
//! regression tripwire; `KIWI_BENCH_RECORD=1` appends the run to
//! `../BENCH_workflow.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::core::process_rss_bytes;
use kiwi::broker::InprocBroker;
use kiwi::communicator::{Communicator, RmqCommunicator, RmqConfig};
use kiwi::daemon::{Daemon, DaemonConfig};
use kiwi::wire::{json, Value};
use kiwi::workflow::checkpoint::{CheckpointStore, FileCheckpointStore, MemoryCheckpointStore};
use kiwi::workflow::{
    ProcessLogic, ProcessRegistry, RemoteLauncher, StepContext, StepOutcome, WaitCondition,
};

fn smoke() -> bool {
    std::env::var("KIWI_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

const MIB: u64 = 1024 * 1024;
const WORKERS: usize = 4;

/// Waits once on a timer, then finishes — the canonical event-engine
/// process: one checkpoint at the wait, no thread parked while waiting.
struct Nap {
    ms: u64,
}
impl ProcessLogic for Nap {
    fn step(&mut self, step: u32, _: &mut StepContext) -> kiwi::Result<StepOutcome> {
        match step {
            0 => Ok(StepOutcome::Wait(WaitCondition::Timer(Duration::from_millis(self.ms)))),
            _ => Ok(StepOutcome::Finish(Value::map([("ok", Value::Bool(true))]))),
        }
    }
    fn save_state(&self) -> Value {
        Value::map([("ms", Value::I64(self.ms as i64))])
    }
    fn load_state(&mut self, state: &Value) -> kiwi::Result<()> {
        let src = state.get_opt("inputs").unwrap_or(state);
        if let Some(ms) = src.get_opt("ms") {
            self.ms = ms.as_i64()? as u64;
        }
        Ok(())
    }
}

/// A flat 5-step process (5 checkpoints), for the store comparison.
struct FiveSteps {
    i: i64,
}
impl ProcessLogic for FiveSteps {
    fn step(&mut self, step: u32, _ctx: &mut StepContext) -> kiwi::Result<StepOutcome> {
        if step >= 4 {
            return Ok(StepOutcome::Finish(Value::I64(self.i)));
        }
        self.i += 1;
        Ok(StepOutcome::Continue)
    }
    fn save_state(&self) -> Value {
        Value::map([("i", Value::I64(self.i))])
    }
    fn load_state(&mut self, state: &Value) -> kiwi::Result<()> {
        self.i = state.get_opt("i").map(|v| v.as_i64()).transpose()?.unwrap_or(0);
        Ok(())
    }
}

fn registry() -> ProcessRegistry {
    let reg = ProcessRegistry::new();
    reg.register("nap", || Box::new(Nap { ms: 1 }));
    reg.register("five", || Box::new(FiveSteps { i: 0 }));
    reg
}

fn live_threads() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

struct CampaignStats {
    wall: Duration,
    proc_s: f64,
    rss_steady: u64,
    threads_peak: usize,
    parked_total: u64,
    resumed_total: u64,
}

/// Wait for `n` completions on the daemon's scheduler while sampling RSS
/// and thread count; returns steady-state (peak-of-sample) readings.
fn await_campaign(daemon: &Daemon, n: u64, t0: Instant) -> CampaignStats {
    let deadline = Instant::now() + Duration::from_secs(600);
    let (mut rss_steady, mut threads_peak) = (0u64, 0usize);
    loop {
        let st = daemon.scheduler().stats();
        rss_steady = rss_steady.max(process_rss_bytes().unwrap_or(0));
        threads_peak = threads_peak.max(live_threads());
        if st.completed_total >= n {
            let wall = t0.elapsed();
            return CampaignStats {
                wall,
                proc_s: n as f64 / wall.as_secs_f64().max(1e-9),
                rss_steady,
                threads_peak,
                parked_total: st.parked_total,
                resumed_total: st.resumed_total,
            };
        }
        assert!(
            Instant::now() < deadline,
            "campaign stalled: {} of {n} processes terminal",
            st.completed_total
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn stack(
    store: Arc<dyn CheckpointStore>,
    max_resident: usize,
) -> (InprocBroker, Daemon, RemoteLauncher) {
    let broker = InprocBroker::new();
    let worker_comm: Arc<dyn Communicator> =
        Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
    let daemon = Daemon::start(
        worker_comm,
        store,
        registry(),
        DaemonConfig {
            workers: WORKERS,
            max_resident_processes: max_resident,
            ..Default::default()
        },
    )
    .unwrap();
    let client: Arc<dyn Communicator> =
        Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
    (broker, daemon, RemoteLauncher::new(client))
}

fn main() {
    let smoke = smoke();
    let campaign_n: usize = if smoke { 2_000 } else { 100_000 };
    let parking_n: usize = if smoke { 500 } else { 5_000 };
    let flat_n: usize = if smoke { 200 } else { 1_000 };

    let mut table = Table::new(
        "E7 workflow engine (event-driven scheduler, 4 workers)",
        &["case", "n", "wall", "proc/s", "rss steady", "threads peak", "parked", "resumed"],
    );

    // Case 1 — the headline campaign: N short-wait processes through the
    // task queue. Prefetch (= max_resident, 1024) meters admission, the
    // timer wheel absorbs the waits, no thread is held per process.
    let threads_baseline = live_threads();
    let campaign = {
        let (_broker, daemon, launcher) = stack(Arc::new(MemoryCheckpointStore::new()), 1024);
        let t0 = Instant::now();
        for _ in 0..campaign_n {
            launcher.launch("nap", Value::Null).unwrap();
        }
        let stats = await_campaign(&daemon, campaign_n as u64, t0);
        daemon.shutdown();
        stats
    };
    assert!(
        campaign.threads_peak < threads_baseline + WORKERS + 64,
        "thread count {} vs baseline {} — scheduler threads must be O(workers), \
         not O({campaign_n} processes)",
        campaign.threads_peak,
        threads_baseline
    );
    table.row(&[
        "campaign (1ms nap)".into(),
        campaign_n.to_string(),
        format!("{:.2?}", campaign.wall),
        format!("{:.0}", campaign.proc_s),
        format!("{} MiB", campaign.rss_steady / MIB),
        campaign.threads_peak.to_string(),
        campaign.parked_total.to_string(),
        campaign.resumed_total.to_string(),
    ]);

    // Case 2 — parking under a tight residency cap: local launches flood
    // the scheduler past max_resident=128; long waits checkpoint, release
    // their slot entirely and resume through the task queue.
    let parking = {
        let (_broker, daemon, _launcher) = stack(Arc::new(MemoryCheckpointStore::new()), 128);
        let t0 = Instant::now();
        for _ in 0..parking_n {
            daemon
                .scheduler()
                .launch("nap", Value::map([("ms", Value::I64(50))]))
                .unwrap();
        }
        let stats = await_campaign(&daemon, parking_n as u64, t0);
        daemon.shutdown();
        stats
    };
    assert!(
        parking.parked_total > 0,
        "a {parking_n}-process flood over max_resident=128 must park some processes"
    );
    assert_eq!(
        parking.parked_total, parking.resumed_total,
        "every parked process must resume through the task queue"
    );
    table.row(&[
        "parked (50ms nap, cap 128)".into(),
        parking_n.to_string(),
        format!("{:.2?}", parking.wall),
        format!("{:.0}", parking.proc_s),
        format!("{} MiB", parking.rss_steady / MIB),
        parking.threads_peak.to_string(),
        parking.parked_total.to_string(),
        parking.resumed_total.to_string(),
    ]);

    // Case 3 — checkpoint-store cost at equal shape: 5 checkpoints per
    // process, memory vs file.
    let ckpt_dir = std::env::temp_dir().join(format!("kiwi-bench-ckpt-{}", std::process::id()));
    std::fs::remove_dir_all(&ckpt_dir).ok();
    let mut flat = Vec::new();
    for (label, store) in [
        ("memory", Arc::new(MemoryCheckpointStore::new()) as Arc<dyn CheckpointStore>),
        (
            "file",
            Arc::new(FileCheckpointStore::open(&ckpt_dir).unwrap()) as Arc<dyn CheckpointStore>,
        ),
    ] {
        let (_broker, daemon, launcher) = stack(store, 1024);
        let t0 = Instant::now();
        for _ in 0..flat_n {
            launcher.launch("five", Value::Null).unwrap();
        }
        let stats = await_campaign(&daemon, flat_n as u64, t0);
        daemon.shutdown();
        table.row(&[
            format!("five-step flat ({label})"),
            flat_n.to_string(),
            format!("{:.2?}", stats.wall),
            format!("{:.0}", stats.proc_s),
            format!("{} MiB", stats.rss_steady / MIB),
            stats.threads_peak.to_string(),
            stats.parked_total.to_string(),
            stats.resumed_total.to_string(),
        ]);
        flat.push((label, stats.proc_s));
    }
    std::fs::remove_dir_all(&ckpt_dir).ok();

    table.emit();
    println!(
        "expected shape: campaign proc/s is step-throughput bound (waits\n\
         cost a timer-wheel entry, not a thread); RSS tracks residency\n\
         (prefetch/max_resident), not campaign size; parking trades proc/s\n\
         for a hard residency cap; file checkpoints cost a constant factor\n\
         over memory (5 json writes per process)."
    );

    let run = Value::map([
        ("bench", Value::from("workflow_engine")),
        ("smoke", Value::from(smoke)),
        ("workers", Value::from(WORKERS)),
        ("campaign_n", Value::from(campaign_n)),
        ("campaign_proc_per_sec", Value::F64(campaign.proc_s)),
        ("campaign_rss_steady_bytes", Value::from(campaign.rss_steady)),
        ("campaign_threads_peak", Value::from(campaign.threads_peak)),
        ("threads_baseline", Value::from(threads_baseline)),
        ("parking_n", Value::from(parking_n)),
        ("parking_proc_per_sec", Value::F64(parking.proc_s)),
        ("parked_total", Value::from(parking.parked_total)),
        ("resumed_total", Value::from(parking.resumed_total)),
        ("flat_n", Value::from(flat_n)),
        ("flat_memory_proc_per_sec", Value::F64(flat[0].1)),
        ("flat_file_proc_per_sec", Value::F64(flat[1].1)),
    ]);
    let path = std::path::Path::new("target/bench-results/BENCH_workflow.json");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(path, json::to_string(&run)) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    if std::env::var("KIWI_BENCH_RECORD").is_ok_and(|v| !v.is_empty() && v != "0") {
        let series_path = std::path::Path::new("../BENCH_workflow.json");
        let mut series = std::fs::read_to_string(series_path)
            .ok()
            .and_then(|t| json::from_str(&t).ok())
            .unwrap_or_else(|| {
                Value::map([
                    ("bench", Value::from("workflow_engine")),
                    ("runs", Value::List(Vec::new())),
                ])
            });
        if let Value::Map(m) = &mut series {
            let runs = m.entry("runs".to_string()).or_insert_with(|| Value::List(Vec::new()));
            if let Value::List(list) = runs {
                list.push(run);
            }
        }
        match std::fs::write(series_path, json::to_string_pretty(&series)) {
            Ok(()) => println!("recorded run into {}", series_path.display()),
            Err(e) => eprintln!("warning: could not record series: {e}"),
        }
    }
}
