//! E7 — workflow-engine throughput (paper §I.C: "scalable from individual
//! laptops ... workflows consisting of varying durations").
//!
//! Processes/second through the full stack (launch task → daemon → runner
//! → checkpoints → terminal broadcast → reply), swept over checkpoint
//! store (memory vs file) and process shape (flat vs nested workchain).

use std::sync::Arc;
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::InprocBroker;
use kiwi::communicator::{Communicator, RmqCommunicator, RmqConfig};
use kiwi::daemon::{Daemon, DaemonConfig};
use kiwi::wire::Value;
use kiwi::workflow::checkpoint::{CheckpointStore, FileCheckpointStore, MemoryCheckpointStore};
use kiwi::workflow::process::{ProcessLogic, StepContext, StepOutcome};
use kiwi::workflow::workchain::{instantiate, ChainStep, WorkChainSpec};
use kiwi::workflow::{ProcessRegistry, RemoteLauncher};

const PROCESSES: usize = 200;

/// A flat 5-step process (5 checkpoints).
struct FiveSteps {
    i: i64,
}
impl ProcessLogic for FiveSteps {
    fn step(&mut self, step: u32, _ctx: &mut StepContext) -> kiwi::Result<StepOutcome> {
        if step >= 4 {
            return Ok(StepOutcome::Finish(Value::I64(self.i)));
        }
        self.i += 1;
        Ok(StepOutcome::Continue)
    }
    fn save_state(&self) -> Value {
        Value::map([("i", Value::I64(self.i))])
    }
    fn load_state(&mut self, state: &Value) -> kiwi::Result<()> {
        self.i = state.get_opt("i").map(|v| v.as_i64()).transpose()?.unwrap_or(0);
        Ok(())
    }
}

fn registry() -> ProcessRegistry {
    let reg = ProcessRegistry::new();
    reg.register("five", || Box::new(FiveSteps { i: 0 }));
    let child = WorkChainSpec::new("leaf")
        .step("go", |_cc, _ctx| Ok(ChainStep::Finish(Value::I64(1))))
        .build();
    reg.register("leaf", move || instantiate(&child));
    let parent = WorkChainSpec::new("nest")
        .step("spawn", |cc, ctx| {
            for _ in 0..4 {
                let pid = ctx.spawn("leaf", Value::Null)?;
                cc.add_child(&pid);
            }
            Ok(ChainStep::WaitChildren)
        })
        .step("done", |cc, _ctx| {
            Ok(ChainStep::Finish(Value::I64(cc.children().len() as i64)))
        })
        .build();
    reg.register("nest", move || instantiate(&parent));
    reg
}

fn run_case(
    store: Arc<dyn CheckpointStore>,
    process_type: &str,
    count: usize,
    workers: usize,
) -> (Duration, f64) {
    let broker = InprocBroker::new();
    let comm: Arc<dyn Communicator> =
        Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
    let daemon = Daemon::start(
        Arc::clone(&comm),
        store,
        registry(),
        DaemonConfig { workers, ..Default::default() },
    )
    .unwrap();
    let client: Arc<dyn Communicator> =
        Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
    let launcher = RemoteLauncher::new(client);
    let t0 = Instant::now();
    let futs: Vec<_> =
        (0..count).map(|_| launcher.launch(process_type, Value::Null).unwrap().1).collect();
    for f in futs {
        let record = f.wait(Duration::from_secs(300)).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
    }
    let wall = t0.elapsed();
    daemon.shutdown();
    (wall, count as f64 / wall.as_secs_f64())
}

fn main() {
    let mut table = Table::new(
        "E7 workflow engine throughput (200 processes, 4 workers)",
        &["process", "checkpoints", "wall", "proc/s"],
    );
    let ckpt_dir = std::env::temp_dir().join(format!("kiwi-bench-ckpt-{}", std::process::id()));
    std::fs::remove_dir_all(&ckpt_dir).ok();

    for (label, store) in [
        ("memory", Arc::new(MemoryCheckpointStore::new()) as Arc<dyn CheckpointStore>),
        ("file", Arc::new(FileCheckpointStore::open(&ckpt_dir).unwrap()) as Arc<dyn CheckpointStore>),
    ] {
        let (wall, thpt) = run_case(Arc::clone(&store), "five", PROCESSES, 4);
        table.row(&["five-step flat".into(), label.into(), format!("{wall:.2?}"), format!("{thpt:.0}")]);
    }
    // Nested workchains: each parent spawns 4 children => 5 processes per
    // submission. Parents hold a worker thread while waiting (synchronous-
    // wait design, DESIGN.md), so keep parents-in-flight below the pool
    // size: submit in waves of 2 on 8 workers.
    {
        let broker = InprocBroker::new();
        let comm: Arc<dyn Communicator> =
            Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
        let daemon = Daemon::start(
            Arc::clone(&comm),
            Arc::new(MemoryCheckpointStore::new()),
            registry(),
            DaemonConfig { workers: 8, ..Default::default() },
        )
        .unwrap();
        let client: Arc<dyn Communicator> =
            Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
        let launcher = RemoteLauncher::new(client);
        let parents = PROCESSES / 4;
        let t0 = Instant::now();
        for wave in (0..parents).step_by(2) {
            let futs: Vec<_> = (wave..(wave + 2).min(parents))
                .map(|_| launcher.launch("nest", Value::Null).unwrap().1)
                .collect();
            for f in futs {
                let record = f.wait(Duration::from_secs(300)).unwrap();
                assert_eq!(record.get_str("state").unwrap(), "finished");
            }
        }
        let wall = t0.elapsed();
        let thpt = parents as f64 / wall.as_secs_f64();
        daemon.shutdown();
        table.row(&[
            "nested 1+4 chain".into(),
            "memory".into(),
            format!("{wall:.2?}"),
            format!("{:.0} parents/s ({:.0} proc/s)", thpt, thpt * 5.0),
        ]);
    }
    std::fs::remove_dir_all(&ckpt_dir).ok();
    table.emit();
    println!("expected shape: file checkpoints cost a constant factor over\n\
              memory (5 json writes per process); nested chains add one\n\
              broadcast round per generation but parallelise across workers.");
}
