//! E-shard — contended multi-queue broker throughput vs. shard count and
//! delivery batch size.
//!
//! Four publisher threads hammer eight queues (round-robin) straight
//! through `BrokerHandle::handle` while one drainer per queue acks
//! everything back. `shards = 1` reproduces the old single-`Mutex<Core>`
//! behaviour; larger shard counts let publishes/acks to different queues
//! proceed in parallel, so on a multi-core host throughput should rise
//! monotonically from shards=1 to shards=4. The second table sweeps the
//! delivery batch at a fixed shard count — batch=1 is the old
//! one-message-per-lock dispatch.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::core::{BrokerConfig, BrokerHandle};
use kiwi::broker::persistence::{NoopPersister, RecoveredState};
use kiwi::broker::protocol::{ClientRequest, MessageProps, QueueOptions, ServerMsg};
use kiwi::wire::Value;

const QUEUES: usize = 8;
const PUBLISHERS: usize = 4;
const TOTAL_MSGS: usize = 24_000; // divisible by QUEUES and PUBLISHERS

fn run_case(shards: usize, delivery_batch: usize) -> (f64, Duration, u64, u64) {
    let broker = BrokerHandle::with_config(
        Box::new(NoopPersister),
        RecoveredState::default(),
        BrokerConfig { shards, delivery_batch, ..Default::default() },
    );
    let per_queue = TOTAL_MSGS / QUEUES;
    let mut drainers = Vec::new();
    for qi in 0..QUEUES {
        let qname = format!("bench.q{qi}");
        let (tx, rx) = channel();
        let conn = broker.connect(&format!("consumer-{qi}"), 0, tx);
        broker
            .handle(
                conn,
                &ClientRequest::QueueDeclare {
                    queue: qname.clone(),
                    options: QueueOptions::default(),
                },
            )
            .unwrap();
        broker
            .handle(
                conn,
                &ClientRequest::Consume {
                    queue: qname,
                    consumer_tag: format!("c{qi}"),
                    prefetch: 0,
                },
            )
            .unwrap();
        let b = broker.clone();
        drainers.push(std::thread::spawn(move || {
            let mut seen = 0usize;
            while seen < per_queue {
                match rx.recv_timeout(Duration::from_secs(60)).expect("delivery") {
                    ServerMsg::Deliver(d) => {
                        b.handle(conn, &ClientRequest::Ack { delivery_tag: d.delivery_tag })
                            .unwrap();
                        seen += 1;
                    }
                    ServerMsg::DeliverBatch(ds) => {
                        let tags: Vec<u64> = ds.iter().map(|d| d.delivery_tag).collect();
                        seen += tags.len();
                        b.handle(conn, &ClientRequest::AckMulti { delivery_tags: tags }).unwrap();
                    }
                    _ => {}
                }
            }
        }));
    }
    let t0 = Instant::now();
    let mut publishers = Vec::new();
    for p in 0..PUBLISHERS {
        let b = broker.clone();
        publishers.push(std::thread::spawn(move || {
            let (tx, _rx) = channel();
            let conn = b.connect(&format!("pub-{p}"), 0, tx);
            let n = TOTAL_MSGS / PUBLISHERS;
            for i in 0..n {
                let q = i % QUEUES;
                b.handle(
                    conn,
                    &ClientRequest::Publish {
                        exchange: "".into(),
                        routing_key: format!("bench.q{q}"),
                        body: kiwi::wire::Bytes::encode(&Value::I64(i as i64)),
                        props: MessageProps::default().into(),
                        mandatory: true,
                    },
                )
                .unwrap();
            }
        }));
    }
    for h in publishers {
        h.join().unwrap();
    }
    for h in drainers {
        h.join().unwrap();
    }
    let elapsed = t0.elapsed();
    (
        TOTAL_MSGS as f64 / elapsed.as_secs_f64(),
        elapsed,
        broker.metrics().counter("broker.route_cache_hits_total").get(),
        broker.metrics().counter("broker.route_cache_misses_total").get(),
    )
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host cores: {cores}\n");

    let mut table = Table::new(
        &format!(
            "E-shard contended throughput ({TOTAL_MSGS} msgs, {QUEUES} queues, \
             {PUBLISHERS} publishers, batch 64)"
        ),
        &["shards", "msgs/s", "wall", "rc_hits", "rc_misses"],
    );
    for &shards in &[1usize, 2, 4, 8] {
        let (thpt, wall, hits, misses) = run_case(shards, 64);
        table.row(&[
            shards.to_string(),
            format!("{thpt:.0}"),
            format!("{wall:.2?}"),
            hits.to_string(),
            misses.to_string(),
        ]);
    }
    table.emit();

    let mut table = Table::new(
        "E-shard delivery-batch sweep (shards=4)",
        &["batch", "msgs/s", "wall", "rc_hits", "rc_misses"],
    );
    for &batch in &[1usize, 8, 64, 256] {
        let (thpt, wall, hits, misses) = run_case(4, batch);
        table.row(&[
            batch.to_string(),
            format!("{thpt:.0}"),
            format!("{wall:.2?}"),
            hits.to_string(),
            misses.to_string(),
        ]);
    }
    table.emit();

    println!(
        "expected shape: on a multi-core host throughput rises monotonically\n\
         from shards=1 (the old single-lock broker) to shards=4, flattening\n\
         once shards exceed cores or queue count; batch=1 reproduces the old\n\
         one-message-per-lock dispatch and should trail larger batches."
    );
}
