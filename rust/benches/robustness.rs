//! E5 — robustness under worker death (paper §I.A: "no task will be
//! lost"). Kill k of 4 workers mid-stream; verify zero loss, count broker
//! requeues, and measure the completion-time inflation.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kiwi::benchutil::Table;
use kiwi::broker::InprocBroker;
use kiwi::communicator::{Communicator, RmqCommunicator, RmqConfig, TaskHandler};
use kiwi::wire::Value;

const TASKS: usize = 400;
const WORKERS: usize = 4;

fn run_case(kill: usize) -> (usize, u64, Duration) {
    let broker = InprocBroker::new();
    let client = RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap();
    let mut workers = Vec::new();
    for _ in 0..WORKERS {
        let comm = Arc::new(
            RmqCommunicator::connect(
                broker.connect(),
                RmqConfig { heartbeat_ms: 50, ..Default::default() },
            )
            .unwrap(),
        );
        let handler: TaskHandler = Box::new(move |_t, ctx| {
            std::thread::sleep(Duration::from_millis(2));
            ctx.complete(Ok(Value::Null));
        });
        comm.task_queue("bench.tasks", 2, handler).unwrap();
        workers.push(comm);
    }

    let t0 = Instant::now();
    let futs: Vec<_> = (0..TASKS)
        .map(|i| client.task_send("bench.tasks", Value::I64(i as i64)).unwrap())
        .collect();

    // Let roughly a quarter of the work complete, then kill k workers
    // abruptly (severed connections, unacked tasks in flight).
    std::thread::sleep(Duration::from_millis(80));
    for w in workers.iter().take(kill) {
        w.close();
    }

    let mut completed = 0;
    for f in futs {
        f.wait(Duration::from_secs(120)).unwrap();
        completed += 1;
    }
    let wall = t0.elapsed();
    let requeued = broker.broker().metrics().counter("broker.requeued_on_death").get();
    (completed, requeued, wall)
}

fn main() {
    let mut table = Table::new(
        "E5 robustness: kill k of 4 workers mid-stream (400 tasks)",
        &["killed", "completed", "lost", "requeued", "wall"],
    );
    let mut baseline = None;
    for &kill in &[0usize, 1, 2, 3] {
        let (completed, requeued, wall) = run_case(kill);
        if kill == 0 {
            baseline = Some(wall);
        }
        table.row(&[
            kill.to_string(),
            completed.to_string(),
            (TASKS - completed).to_string(),
            requeued.to_string(),
            format!(
                "{wall:.2?} ({:.1}x)",
                wall.as_secs_f64() / baseline.unwrap().as_secs_f64()
            ),
        ]);
        assert_eq!(completed, TASKS, "paper claim: zero loss, killed={kill}");
    }
    table.emit();
    println!("expected shape: zero losses always; wall time inflates roughly\n\
              by the lost worker fraction; requeued == in-flight prefetch\n\
              of the killed workers.");
}
