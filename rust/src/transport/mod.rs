//! Client-side transport: a [`Link`] abstraction (framed, bidirectional,
//! thread-safe send) with TCP and in-process implementations, plus the
//! reconnecting connection used by the communicator.

pub mod conn;
pub mod link;

pub use conn::{Connection, ConnectionConfig};
pub use link::{connect_tcp, inproc_pair, Link};
