//! Client-side transport: a [`Link`] abstraction (framed, bidirectional,
//! thread-safe send) with TCP and in-process implementations, plus the
//! reconnecting [`Connection`] used by the communicator — opened with a
//! [`LinkFactory`] it survives broker outages by re-dialing with capped
//! exponential backoff and replaying its topology journal (exchanges,
//! queues, bindings, consumers), so handlers keep firing across a broker
//! restart with no user code (see [`reconnect`]).

pub mod conn;
pub mod link;
pub mod reconnect;

pub use conn::{Connection, ConnectionConfig};
pub use link::{connect_tcp, connect_tcp_bounded, inproc_pair, Link};
pub use reconnect::{tcp_factory, LinkFactory, TopologyJournal};
