//! Connection resilience: the mechanism behind the transparently
//! reconnecting [`Connection`](crate::transport::Connection).
//!
//! Three pieces, all driven by the communication thread:
//!
//! * [`LinkFactory`] — how to dial the broker again. A connection opened
//!   with a factory survives link death; one opened around a bare link
//!   keeps the old fail-fast behaviour.
//! * [`LinkSlot`] — the current link stamped with an *epoch*. Senders read
//!   `(link, epoch)` atomically; a failure report carrying a stale epoch is
//!   ignored, so an old link's death can never tear down its replacement,
//!   and sends during an outage fail fast (retryable) instead of
//!   interleaving onto a half-dead socket.
//! * [`TopologyJournal`] — everything the broker must be re-taught after a
//!   restart: exchanges, queues, bindings and consumers, recorded as the
//!   live connection declares them and replayed in dependency order
//!   (exchanges → queues → bindings → consumers) on revival.
//!
//! Re-dials back off exponentially (base `reconnect_backoff_ms`, doubling,
//! capped at 32× base) with uniform jitter in `[0, delay/2)` so a herd of
//! daemons does not stampede a broker that just came back.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::broker::protocol::{ClientRequest, ExchangeKind, QueueOptions};
use crate::error::{Error, Result};
use crate::transport::Link;

/// Produces a fresh link to the broker. Called once per dial attempt, from
/// the communication thread.
pub type LinkFactory = Box<dyn Fn() -> Result<Arc<dyn Link>> + Send + Sync>;

/// Per-dial budget for [`tcp_factory`]: bounds how long one reconnect
/// attempt (and therefore a `close()` that joins mid-dial) can block on a
/// blackholed host.
pub const TCP_DIAL_TIMEOUT: Duration = Duration::from_secs(5);

/// Build a [`LinkFactory`] that dials `addr` over TCP — the standard way to
/// get a reconnecting connection to a remote broker. Each dial is bounded
/// by [`TCP_DIAL_TIMEOUT`].
pub fn tcp_factory(addr: impl Into<String>) -> LinkFactory {
    let addr = addr.into();
    Box::new(move || {
        let link = crate::transport::link::connect_tcp_bounded(&addr, TCP_DIAL_TIMEOUT)?;
        Ok(Arc::new(link) as Arc<dyn Link>)
    })
}

/// Backoff for dial attempt `attempt` (0-based; attempt 0 is immediate):
/// `min(base << (attempt-1), base * 32)` plus jitter in `[0, delay/2)`.
pub(crate) fn backoff_delay(attempt: u32, base_ms: u64, jitter: u64) -> Duration {
    if attempt == 0 {
        return Duration::ZERO;
    }
    let base = base_ms.max(1);
    let exp = (attempt - 1).min(5); // 2^5 = 32× cap
    let delay = base.saturating_mul(1u64 << exp);
    Duration::from_millis(delay + jitter % (delay / 2 + 1))
}

// ---------------------------------------------------------------- slot --

/// Lifecycle of the slot's link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Phase {
    /// Link believed healthy; senders use it.
    Up,
    /// Link dead, reconnect in progress; sends fail fast (retryable).
    Down,
    /// Connection permanently closed; sends fail terminally.
    Closed,
}

struct SlotState {
    link: Arc<dyn Link>,
    epoch: u64,
    phase: Phase,
}

/// The current link + epoch, with a condvar so parked senders learn about
/// revival (and `close()` interrupts any backoff sleep promptly).
pub(crate) struct LinkSlot {
    state: Mutex<SlotState>,
    cond: Condvar,
}

impl LinkSlot {
    pub fn new(link: Arc<dyn Link>) -> Self {
        LinkSlot {
            state: Mutex::new(SlotState { link, epoch: 0, phase: Phase::Up }),
            cond: Condvar::new(),
        }
    }

    /// The live link and its epoch, or a retryable/terminal error.
    pub fn current(&self) -> Result<(Arc<dyn Link>, u64)> {
        let st = self.state.lock().unwrap();
        match st.phase {
            Phase::Up => Ok((Arc::clone(&st.link), st.epoch)),
            Phase::Down => Err(Error::Closed("connection lost (reconnecting)".into())),
            Phase::Closed => Err(Error::Closed("connection closed".into())),
        }
    }

    /// Park until the slot is `Up` (revival) or `deadline` passes. Used by
    /// `request` to ride out an outage instead of failing with `Closed`.
    pub fn await_up(&self, deadline: Instant) -> Result<(Arc<dyn Link>, u64)> {
        let mut st = self.state.lock().unwrap();
        loop {
            match st.phase {
                Phase::Up => return Ok((Arc::clone(&st.link), st.epoch)),
                Phase::Closed => return Err(Error::Closed("connection closed".into())),
                Phase::Down => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(Error::Timeout("request parked across outage".into()));
                    }
                    let (guard, _) = self.cond.wait_timeout(st, deadline - now).unwrap();
                    st = guard;
                }
            }
        }
    }

    /// Report that the link stamped `epoch` failed. Stale reports (an older
    /// link's death, observed after a successful reconnect) are ignored.
    /// Closes the dead link so the communication thread's blocking `recv`
    /// wakes and drives recovery. Returns true if this report transitioned
    /// the slot `Up → Down`.
    pub fn report_failure(&self, epoch: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.phase != Phase::Up || st.epoch != epoch {
            return false;
        }
        st.phase = Phase::Down;
        st.link.close();
        self.cond.notify_all();
        true
    }

    /// Install a freshly dialed (and replayed) link; bumps the epoch and
    /// wakes every parked sender. Refused (`None`, severing the link) when
    /// the slot was closed while the dial/replay ran — a completing
    /// reconnect must not race `close()` back to life, or the fresh
    /// broker session (with its replayed consumers) would leak, soaking up
    /// deliveries nobody reads.
    pub fn install(&self, link: Arc<dyn Link>) -> Option<u64> {
        let mut st = self.state.lock().unwrap();
        if st.phase == Phase::Closed {
            link.close();
            return None;
        }
        st.link = link;
        st.epoch += 1;
        st.phase = Phase::Up;
        self.cond.notify_all();
        Some(st.epoch)
    }

    /// Permanently close: terminal phase, current link severed, everyone
    /// woken (parked senders fail with `Closed`; backoff sleeps abort).
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.phase = Phase::Closed;
        st.link.close();
        self.cond.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().phase == Phase::Closed
    }

    /// Interruptible backoff sleep: returns false if the slot was closed
    /// while sleeping (caller must abandon the reconnect).
    pub fn sleep_unless_closed(&self, dur: Duration) -> bool {
        let deadline = Instant::now() + dur;
        let mut st = self.state.lock().unwrap();
        loop {
            if st.phase == Phase::Closed {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return true;
            }
            let (guard, _) = self.cond.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

// ------------------------------------------------------------- journal --

/// A consumer registration to be re-issued on revival.
#[derive(Clone, Debug, PartialEq)]
pub struct ConsumerSpec {
    pub consumer_tag: String,
    pub queue: String,
    pub prefetch: u32,
    /// `Some(group)` for stream consumers — replayed as `StreamConsume`
    /// with no seek offset, so the group resumes from its committed
    /// cursor (the broker holds the position; re-seeking would rewind
    /// every surviving member).
    pub group: Option<String>,
}

/// Topology recorded on the live connection and replayed after a
/// reconnect, so a broker that lost its state (process restart) is
/// re-taught every exchange, queue, binding and consumer without any user
/// code. Entries are deduplicated and kept in dependency order.
#[derive(Default)]
pub struct TopologyJournal {
    exchanges: Vec<(String, ExchangeKind)>,
    queues: Vec<(String, QueueOptions)>,
    /// (exchange, queue, routing_key)
    bindings: Vec<(String, String, String)>,
    consumers: Vec<ConsumerSpec>,
}

impl TopologyJournal {
    /// Record the effect of a *successfully acknowledged* request. Called
    /// from the request path, so everything the broker accepted — and
    /// nothing it refused — lands in the journal.
    pub fn observe(&mut self, req: &ClientRequest) {
        match req {
            ClientRequest::ExchangeDeclare { exchange, kind } => {
                match self.exchanges.iter_mut().find(|(e, _)| e == exchange) {
                    Some(entry) => entry.1 = *kind,
                    None => self.exchanges.push((exchange.clone(), *kind)),
                }
            }
            ClientRequest::QueueDeclare { queue, options } => {
                match self.queues.iter_mut().find(|(q, _)| q == queue) {
                    Some(entry) => entry.1 = options.clone(),
                    None => self.queues.push((queue.clone(), options.clone())),
                }
            }
            ClientRequest::Bind { exchange, queue, routing_key } => {
                let b = (exchange.clone(), queue.clone(), routing_key.clone());
                if !self.bindings.contains(&b) {
                    self.bindings.push(b);
                }
            }
            ClientRequest::Unbind { exchange, queue, routing_key } => {
                self.bindings
                    .retain(|(e, q, k)| !(e == exchange && q == queue && k == routing_key));
            }
            ClientRequest::QueueDelete { queue } => {
                self.queues.retain(|(q, _)| q != queue);
                self.bindings.retain(|(_, q, _)| q != queue);
                self.consumers.retain(|c| &c.queue != queue);
            }
            _ => {}
        }
    }

    pub fn record_consumer(&mut self, consumer_tag: &str, queue: &str, prefetch: u32) {
        self.remove_consumer(consumer_tag);
        self.consumers.push(ConsumerSpec {
            consumer_tag: consumer_tag.to_string(),
            queue: queue.to_string(),
            prefetch,
            group: None,
        });
    }

    pub fn record_stream_consumer(
        &mut self,
        consumer_tag: &str,
        queue: &str,
        group: &str,
        prefetch: u32,
    ) {
        self.remove_consumer(consumer_tag);
        self.consumers.push(ConsumerSpec {
            consumer_tag: consumer_tag.to_string(),
            queue: queue.to_string(),
            prefetch,
            group: Some(group.to_string()),
        });
    }

    pub fn remove_consumer(&mut self, consumer_tag: &str) {
        self.consumers.retain(|c| c.consumer_tag != consumer_tag);
    }

    /// Declaration requests in replay order (exchanges → queues →
    /// bindings); consumers are re-issued separately so the caller can
    /// count them and skip tags whose handlers are gone.
    pub fn replay_requests(&self) -> Vec<ClientRequest> {
        let mut reqs = Vec::with_capacity(
            self.exchanges.len() + self.queues.len() + self.bindings.len(),
        );
        for (exchange, kind) in &self.exchanges {
            reqs.push(ClientRequest::ExchangeDeclare { exchange: exchange.clone(), kind: *kind });
        }
        for (queue, options) in &self.queues {
            reqs.push(ClientRequest::QueueDeclare {
                queue: queue.clone(),
                options: options.clone(),
            });
        }
        for (exchange, queue, routing_key) in &self.bindings {
            reqs.push(ClientRequest::Bind {
                exchange: exchange.clone(),
                queue: queue.clone(),
                routing_key: routing_key.clone(),
            });
        }
        reqs
    }

    pub fn consumers(&self) -> Vec<ConsumerSpec> {
        self.consumers.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn journal_orders_and_dedupes() {
        let mut j = TopologyJournal::default();
        j.observe(&ClientRequest::Bind {
            exchange: "x".into(),
            queue: "q".into(),
            routing_key: "k".into(),
        });
        j.observe(&ClientRequest::QueueDeclare {
            queue: "q".into(),
            options: QueueOptions::default(),
        });
        j.observe(&ClientRequest::ExchangeDeclare {
            exchange: "x".into(),
            kind: ExchangeKind::Direct,
        });
        // Re-declares overwrite, not duplicate.
        j.observe(&ClientRequest::QueueDeclare {
            queue: "q".into(),
            options: QueueOptions { durable: true, ..Default::default() },
        });
        j.observe(&ClientRequest::Bind {
            exchange: "x".into(),
            queue: "q".into(),
            routing_key: "k".into(),
        });
        let reqs = j.replay_requests();
        assert_eq!(reqs.len(), 3, "{reqs:?}");
        let is_x = |r: &ClientRequest| {
            matches!(r, ClientRequest::ExchangeDeclare { exchange, .. } if exchange == "x")
        };
        assert!(is_x(&reqs[0]));
        let durable_q = |r: &ClientRequest| match r {
            ClientRequest::QueueDeclare { queue, options } => queue == "q" && options.durable,
            _ => false,
        };
        assert!(durable_q(&reqs[1]));
        assert!(matches!(&reqs[2], ClientRequest::Bind { .. }));
    }

    #[test]
    fn journal_forgets_deleted_topology() {
        let mut j = TopologyJournal::default();
        j.observe(&ClientRequest::QueueDeclare {
            queue: "q".into(),
            options: QueueOptions::default(),
        });
        j.observe(&ClientRequest::Bind {
            exchange: "x".into(),
            queue: "q".into(),
            routing_key: "k".into(),
        });
        j.record_consumer("c1", "q", 4);
        j.observe(&ClientRequest::Unbind {
            exchange: "x".into(),
            queue: "q".into(),
            routing_key: "k".into(),
        });
        assert!(j.replay_requests().iter().all(|r| !matches!(r, ClientRequest::Bind { .. })));
        j.observe(&ClientRequest::QueueDelete { queue: "q".into() });
        assert!(j.replay_requests().is_empty());
        assert!(j.consumers().is_empty());
    }

    #[test]
    fn consumer_records_replace_by_tag() {
        let mut j = TopologyJournal::default();
        j.record_consumer("c1", "a", 1);
        j.record_consumer("c1", "b", 2);
        assert_eq!(j.consumers(), vec![ConsumerSpec {
            consumer_tag: "c1".into(),
            queue: "b".into(),
            prefetch: 2,
            group: None,
        }]);
        // A stream re-registration replaces the work-queue record by tag.
        j.record_stream_consumer("c1", "b", "g", 2);
        assert_eq!(j.consumers()[0].group.as_deref(), Some("g"));
        j.remove_consumer("c1");
        assert!(j.consumers().is_empty());
    }

    #[test]
    fn backoff_is_capped_and_jittered() {
        let base = 100;
        assert_eq!(backoff_delay(0, base, 7), Duration::ZERO);
        // attempt 1 = base .. 1.5*base
        let d1 = backoff_delay(1, base, 0);
        assert_eq!(d1, Duration::from_millis(100));
        let d1j = backoff_delay(1, base, 49);
        assert!(d1j >= d1 && d1j < Duration::from_millis(151), "{d1j:?}");
        // Far attempts cap at 32x base (+ jitter < half).
        for attempt in [6, 7, 20, u32::MAX] {
            let d = backoff_delay(attempt, base, u64::MAX - 3);
            assert!(d >= Duration::from_millis(3200), "{attempt}: {d:?}");
            assert!(d < Duration::from_millis(3200 + 1601), "{attempt}: {d:?}");
        }
    }

    #[test]
    fn slot_epochs_reject_stale_failure_reports() {
        let (a, _a_peer) = crate::transport::link::inproc_pair();
        let slot = LinkSlot::new(Arc::new(a));
        let (_, e0) = slot.current().unwrap();
        assert!(slot.report_failure(e0));
        assert!(slot.current().is_err(), "down slot must fail senders fast");
        let (b, _b_peer) = crate::transport::link::inproc_pair();
        let e1 = slot.install(Arc::new(b)).unwrap();
        assert_ne!(e0, e1);
        // A late report about the dead epoch must not poison the new link.
        assert!(!slot.report_failure(e0));
        assert!(slot.current().is_ok());
        slot.close();
        assert!(slot.is_closed());
        assert!(!slot.report_failure(e1));
        // A reconnect completing after close() must not resurrect the slot.
        let (c, _c_peer) = crate::transport::link::inproc_pair();
        assert!(slot.install(Arc::new(c)).is_none());
        assert!(slot.is_closed());
    }

    #[test]
    fn await_up_wakes_on_install() {
        let (a, _a_peer) = crate::transport::link::inproc_pair();
        let slot = Arc::new(LinkSlot::new(Arc::new(a)));
        let (_, e0) = slot.current().unwrap();
        slot.report_failure(e0);
        let slot2 = Arc::clone(&slot);
        let waiter = std::thread::spawn(move || {
            slot2.await_up(Instant::now() + Duration::from_secs(5)).map(|(_, e)| e)
        });
        std::thread::sleep(Duration::from_millis(30));
        let (b, _b_peer) = crate::transport::link::inproc_pair();
        let e1 = slot.install(Arc::new(b)).unwrap();
        assert_eq!(waiter.join().unwrap().unwrap(), e1);
    }

    #[test]
    fn close_interrupts_backoff_sleep() {
        let (a, _a_peer) = crate::transport::link::inproc_pair();
        let slot = Arc::new(LinkSlot::new(Arc::new(a)));
        let slot2 = Arc::clone(&slot);
        let sleeper =
            std::thread::spawn(move || slot2.sleep_unless_closed(Duration::from_secs(60)));
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        slot.close();
        assert!(!sleeper.join().unwrap(), "sleep must report closure");
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
