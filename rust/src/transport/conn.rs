//! Client connection: request/reply correlation, consumer delivery
//! dispatch, and heartbeats — all driven by a hidden communication thread,
//! kiwiPy's signature usability feature ("a separate communication thread
//! that the user never sees", maintaining heartbeats "whilst the user code
//! can be doing other things").

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::broker::protocol::{ClientRequest, Delivery, ServerMsg};
use crate::error::{Error, Result};
use crate::transport::Link;
use crate::wire::{Frame, FrameType};

/// Callback invoked on the communication thread for each delivery.
pub type DeliveryHandler = Box<dyn FnMut(Delivery) + Send>;

/// Connection tuning knobs.
#[derive(Clone, Debug)]
pub struct ConnectionConfig {
    /// Identity announced in `Hello` (shows up in broker logs).
    pub client_id: String,
    /// Heartbeat interval; 0 disables. Two missed intervals and the broker
    /// evicts us (requeueing our unacked messages); symmetrically we treat
    /// a silent broker as dead after two intervals.
    pub heartbeat_ms: u64,
    /// Default timeout for request/reply calls.
    pub request_timeout: Duration,
}

impl Default for ConnectionConfig {
    fn default() -> Self {
        ConnectionConfig {
            client_id: format!("kiwi-{}", std::process::id()),
            heartbeat_ms: 0,
            request_timeout: Duration::from_secs(10),
        }
    }
}

struct Shared {
    link: Arc<dyn Link>,
    next_req: AtomicU64,
    pending: Mutex<HashMap<u64, Sender<ServerMsg>>>,
    handlers: Mutex<HashMap<String, DeliveryHandler>>,
    closed: AtomicBool,
    /// Instant of the last frame seen from the broker (liveness).
    last_server_frame: Mutex<Instant>,
    /// Ack pipeline: `Some` while a delivery batch is being dispatched on
    /// the communication thread; acks issued in that window buffer here
    /// and go out as one `AckMulti` frame at the end of the batch.
    ack_buffer: Mutex<Option<Vec<u64>>>,
}

impl Shared {
    fn mark_closed(&self) {
        if !self.closed.swap(true, Ordering::SeqCst) {
            // Fail every waiter.
            let mut pending = self.pending.lock().unwrap();
            pending.clear(); // dropping senders wakes receivers with Closed
        }
    }

    /// Fire-and-forget send: no reply waited for (the broker's Ok is
    /// dropped by the reader when no waiter is found).
    fn send_noreply(&self, req: &ClientRequest) -> Result<()> {
        if self.closed.load(Ordering::Relaxed) {
            return Err(Error::Closed("connection closed".into()));
        }
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        self.link.send(&req.to_frame(req_id)).map_err(|e| {
            self.mark_closed();
            e
        })
    }

    /// Close the window and flush everything buffered as a single frame.
    fn flush_ack_window(&self) {
        let tags = self.ack_buffer.lock().unwrap().take();
        let Some(tags) = tags else { return };
        let req = match tags.len() {
            0 => return,
            1 => ClientRequest::Ack { delivery_tag: tags[0] },
            _ => ClientRequest::AckMulti { delivery_tags: tags },
        };
        self.send_noreply(&req).ok();
    }
}

/// RAII handle for the ack-coalescing window: flushes on drop, so the
/// window closes — and buffered acks still go out — even if a delivery
/// handler panics mid-batch.
struct AckWindow {
    shared: Arc<Shared>,
}

/// Open the ack-coalescing window (communication thread only).
fn open_ack_window(shared: &Arc<Shared>) -> AckWindow {
    *shared.ack_buffer.lock().unwrap() = Some(Vec::new());
    AckWindow { shared: Arc::clone(shared) }
}

impl Drop for AckWindow {
    fn drop(&mut self) {
        self.shared.flush_ack_window();
    }
}

/// A client connection to a broker (TCP or in-process — any [`Link`]).
pub struct Connection {
    shared: Arc<Shared>,
    config: ConnectionConfig,
    reader: Mutex<Option<JoinHandle<()>>>,
    heartbeater: Mutex<Option<JoinHandle<()>>>,
}

impl Connection {
    /// Open a connection over `link`: spawn the communication thread, send
    /// `Hello`, wait for the broker's ack.
    pub fn open(link: Arc<dyn Link>, config: ConnectionConfig) -> Result<Self> {
        let shared = Arc::new(Shared {
            link: Arc::clone(&link),
            next_req: AtomicU64::new(1),
            pending: Mutex::new(HashMap::new()),
            handlers: Mutex::new(HashMap::new()),
            closed: AtomicBool::new(false),
            last_server_frame: Mutex::new(Instant::now()),
            ack_buffer: Mutex::new(None),
        });

        let reader = {
            let shared = Arc::clone(&shared);
            let hb = config.heartbeat_ms;
            std::thread::Builder::new()
                .name("kiwi-comm".into())
                .spawn(move || reader_loop(shared, hb))
                .expect("spawn communication thread")
        };

        let heartbeater = if config.heartbeat_ms > 0 {
            let shared = Arc::clone(&shared);
            let interval = Duration::from_millis((config.heartbeat_ms / 2).max(1));
            Some(
                std::thread::Builder::new()
                    .name("kiwi-heartbeat".into())
                    .spawn(move || {
                        while !shared.closed.load(Ordering::Relaxed) {
                            std::thread::sleep(interval);
                            if shared.link.send(&Frame::heartbeat()).is_err() {
                                shared.mark_closed();
                                break;
                            }
                        }
                    })
                    .expect("spawn heartbeater"),
            )
        } else {
            None
        };

        let conn = Connection {
            shared,
            config: config.clone(),
            reader: Mutex::new(Some(reader)),
            heartbeater: Mutex::new(heartbeater),
        };
        conn.request(&ClientRequest::Hello {
            client_id: config.client_id.clone(),
            heartbeat_ms: config.heartbeat_ms,
        })?;
        Ok(conn)
    }

    /// Send a request and wait for the broker's reply.
    pub fn request(&self, req: &ClientRequest) -> Result<crate::wire::Value> {
        self.request_timeout(req, self.config.request_timeout)
    }

    /// Send a request and wait up to `timeout`.
    pub fn request_timeout(
        &self,
        req: &ClientRequest,
        timeout: Duration,
    ) -> Result<crate::wire::Value> {
        if self.shared.closed.load(Ordering::Relaxed) {
            return Err(Error::Closed("connection closed".into()));
        }
        let req_id = self.shared.next_req.fetch_add(1, Ordering::Relaxed);
        let (tx, rx): (Sender<ServerMsg>, Receiver<ServerMsg>) = channel();
        self.shared.pending.lock().unwrap().insert(req_id, tx);
        if let Err(e) = self.shared.link.send(&req.to_frame(req_id)) {
            self.shared.pending.lock().unwrap().remove(&req_id);
            self.shared.mark_closed();
            return Err(e);
        }
        match rx.recv_timeout(timeout) {
            Ok(ServerMsg::Ok { reply, .. }) => Ok(reply),
            Ok(ServerMsg::Err { code, message, .. }) => Err(decode_remote_error(&code, message)),
            Ok(other) => Err(Error::Wire(format!("unexpected reply {other:?}"))),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                self.shared.pending.lock().unwrap().remove(&req_id);
                Err(Error::Timeout(format!("request {req_id}")))
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Closed("connection lost".into()))
            }
        }
    }

    /// Fire-and-forget request (acks on the hot path): no reply waited for;
    /// the broker's Ok is dropped by the reader when no waiter is found.
    pub fn send_noreply(&self, req: &ClientRequest) -> Result<()> {
        self.shared.send_noreply(req)
    }

    /// Start consuming `queue`: registers `handler` (invoked on the
    /// communication thread) and issues `Consume`.
    pub fn consume(
        &self,
        queue: &str,
        consumer_tag: &str,
        prefetch: u32,
        handler: DeliveryHandler,
    ) -> Result<()> {
        self.shared.handlers.lock().unwrap().insert(consumer_tag.to_string(), handler);
        let res = self.request(&ClientRequest::Consume {
            queue: queue.to_string(),
            consumer_tag: consumer_tag.to_string(),
            prefetch,
        });
        if res.is_err() {
            self.shared.handlers.lock().unwrap().remove(consumer_tag);
        }
        res.map(|_| ())
    }

    /// Stop consuming.
    pub fn cancel(&self, consumer_tag: &str) -> Result<()> {
        self.request(&ClientRequest::Cancel { consumer_tag: consumer_tag.to_string() })?;
        self.shared.handlers.lock().unwrap().remove(consumer_tag);
        Ok(())
    }

    /// Acknowledge a delivery (fire-and-forget). Acks issued while the
    /// communication thread is dispatching a delivery batch are pipelined:
    /// they buffer and leave as one `AckMulti` frame when the batch ends.
    pub fn ack(&self, delivery_tag: u64) -> Result<()> {
        if self.shared.closed.load(Ordering::Relaxed) {
            return Err(Error::Closed("connection closed".into()));
        }
        {
            let mut buf = self.shared.ack_buffer.lock().unwrap();
            if let Some(tags) = buf.as_mut() {
                tags.push(delivery_tag);
                return Ok(());
            }
        }
        self.send_noreply(&ClientRequest::Ack { delivery_tag })
    }

    /// Reject a delivery, optionally requeueing (fire-and-forget). With
    /// `requeue = false` — or when the message has hit its queue's
    /// `max_delivery` cap — the broker dead-letters it instead of
    /// redelivering.
    pub fn nack(&self, delivery_tag: u64, requeue: bool) -> Result<()> {
        self.send_noreply(&ClientRequest::Nack { delivery_tag, requeue })
    }

    /// Negative-acknowledge many deliveries in one frame.
    pub fn nack_multi(&self, delivery_tags: Vec<u64>, requeue: bool) -> Result<()> {
        if delivery_tags.is_empty() {
            return Ok(());
        }
        self.send_noreply(&ClientRequest::NackMulti { delivery_tags, requeue })
    }

    /// AMQP `basic.reject`: refuse a single delivery (fire-and-forget).
    /// Same broker semantics as [`Connection::nack`].
    pub fn reject(&self, delivery_tag: u64, requeue: bool) -> Result<()> {
        self.send_noreply(&ClientRequest::Reject { delivery_tag, requeue })
    }

    /// True when the connection is no longer usable.
    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Relaxed)
    }

    /// Graceful close: `Close` to the broker, stop threads, clear delivery
    /// handlers (breaking any `Arc<Connection>` cycles closures hold).
    /// Idempotent; callable from any thread except the communication
    /// thread itself.
    pub fn close(&self) {
        if !self.shared.closed.load(Ordering::Relaxed) {
            self.request_timeout(&ClientRequest::Close, Duration::from_millis(500)).ok();
        }
        self.shared.mark_closed();
        self.shared.link.close();
        if let Some(h) = self.reader.lock().unwrap().take() {
            h.join().ok();
        }
        if let Some(h) = self.heartbeater.lock().unwrap().take() {
            h.join().ok();
        }
        self.shared.handlers.lock().unwrap().clear();
    }
}

impl Drop for Connection {
    fn drop(&mut self) {
        self.close();
    }
}

fn decode_remote_error(code: &str, message: String) -> Error {
    match code {
        "unroutable" => Error::UnroutableMessage(message),
        "duplicate-subscriber" => Error::DuplicateSubscriber(message),
        "timeout" => Error::Timeout(message),
        "remote-exception" => Error::RemoteException(message),
        _ => Error::Broker(message),
    }
}

/// The hidden communication thread: demultiplexes replies, deliveries and
/// server heartbeats.
fn reader_loop(shared: Arc<Shared>, heartbeat_ms: u64) {
    let poll = Duration::from_millis(if heartbeat_ms > 0 { (heartbeat_ms / 2).max(1) } else { 200 });
    loop {
        if shared.closed.load(Ordering::Relaxed) {
            break;
        }
        match shared.link.recv_timeout(poll) {
            Ok(frame) => {
                *shared.last_server_frame.lock().unwrap() = Instant::now();
                match frame.frame_type {
                    FrameType::Heartbeat => {}
                    FrameType::Goodbye => {
                        log::debug!("connection: broker said goodbye");
                        shared.mark_closed();
                        break;
                    }
                    FrameType::Data => match ServerMsg::from_frame(&frame) {
                        Ok(ServerMsg::Deliver(d)) => {
                            let mut handlers = shared.handlers.lock().unwrap();
                            if let Some(h) = handlers.get_mut(&d.consumer_tag) {
                                h(d);
                            } else {
                                log::warn!(
                                    "connection: delivery for unknown consumer '{}'",
                                    d.consumer_tag
                                );
                            }
                        }
                        Ok(ServerMsg::DeliverBatch(ds)) => {
                            // Dispatch the whole batch with the ack window
                            // open: handler acks coalesce into one AckMulti
                            // frame sent when the batch is done. The guard
                            // flushes on drop (panic-safe).
                            let window = open_ack_window(&shared);
                            {
                                let mut handlers = shared.handlers.lock().unwrap();
                                for d in ds {
                                    if let Some(h) = handlers.get_mut(&d.consumer_tag) {
                                        h(d);
                                    } else {
                                        log::warn!(
                                            "connection: delivery for unknown consumer '{}'",
                                            d.consumer_tag
                                        );
                                    }
                                }
                            }
                            drop(window);
                        }
                        Ok(ServerMsg::CancelConsumer { consumer_tag }) => {
                            shared.handlers.lock().unwrap().remove(&consumer_tag);
                        }
                        Ok(msg @ (ServerMsg::Ok { .. } | ServerMsg::Err { .. })) => {
                            let req_id = match &msg {
                                ServerMsg::Ok { req_id, .. } | ServerMsg::Err { req_id, .. } => {
                                    *req_id
                                }
                                _ => unreachable!(),
                            };
                            if let Some(tx) = shared.pending.lock().unwrap().remove(&req_id) {
                                tx.send(msg).ok();
                            }
                            // No waiter = fire-and-forget request; drop.
                        }
                        Err(e) => {
                            log::warn!("connection: bad frame from broker: {e}");
                            shared.mark_closed();
                            break;
                        }
                    },
                }
            }
            Err(Error::Timeout(_)) => {
                // Detect a dead broker: two missed heartbeat intervals.
                if heartbeat_ms > 0 {
                    let last = *shared.last_server_frame.lock().unwrap();
                    if last.elapsed().as_millis() as u64 > 2 * heartbeat_ms {
                        log::warn!("connection: broker silent for 2 heartbeat intervals");
                        shared.mark_closed();
                        break;
                    }
                }
            }
            Err(_) => {
                shared.mark_closed();
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::QueueOptions;
    use crate::broker::InprocBroker;
    use crate::wire::{Bytes, Value};

    fn open(broker: &InprocBroker) -> Connection {
        Connection::open(broker.connect(), ConnectionConfig::default()).unwrap()
    }

    #[test]
    fn hello_and_declare() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        let reply = conn
            .request(&ClientRequest::QueueDeclare {
                queue: "q".into(),
                options: QueueOptions::default(),
            })
            .unwrap();
        assert_eq!(reply.get_str("queue").unwrap(), "q");
        conn.close();
    }

    #[test]
    fn consume_dispatches_to_handler() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        conn.request(&ClientRequest::QueueDeclare {
            queue: "q".into(),
            options: QueueOptions::default(),
        })
        .unwrap();
        let (tx, rx) = channel();
        conn.consume(
            "q",
            "c1",
            0,
            Box::new(move |d| {
                tx.send(d.body.decode().unwrap()).unwrap();
            }),
        )
        .unwrap();
        conn.request(&ClientRequest::Publish {
            exchange: "".into(),
            routing_key: "q".into(),
            body: Bytes::encode(&Value::str("hi")),
            props: Default::default(),
            mandatory: true,
        })
        .unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(2)).unwrap(), Value::str("hi"));
        conn.close();
    }

    #[test]
    fn broker_error_becomes_typed_error() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        let err = conn
            .request(&ClientRequest::Publish {
                exchange: "".into(),
                routing_key: "missing".into(),
                body: Bytes::encode(&Value::Null),
                props: Default::default(),
                mandatory: true,
            })
            .unwrap_err();
        assert!(matches!(err, Error::UnroutableMessage(_)));
        conn.close();
    }

    #[test]
    fn concurrent_requests_from_many_threads() {
        let broker = InprocBroker::new();
        let conn = Arc::new(open(&broker));
        conn.request(&ClientRequest::QueueDeclare {
            queue: "q".into(),
            options: QueueOptions::default(),
        })
        .unwrap();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let conn = Arc::clone(&conn);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        conn.request(&ClientRequest::Publish {
                            exchange: "".into(),
                            routing_key: "q".into(),
                            body: Bytes::encode(&Value::I64(t * 1000 + i)),
                            props: Default::default(),
                            mandatory: true,
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(broker.broker().queue_depth("q"), Some(400));
    }

    #[test]
    fn ack_fire_and_forget_drains_queue() {
        let broker = InprocBroker::new();
        let conn = Arc::new(open(&broker));
        conn.request(&ClientRequest::QueueDeclare {
            queue: "q".into(),
            options: QueueOptions::default(),
        })
        .unwrap();
        for i in 0..10 {
            conn.request(&ClientRequest::Publish {
                exchange: "".into(),
                routing_key: "q".into(),
                body: Bytes::encode(&Value::I64(i)),
                props: Default::default(),
                mandatory: true,
            })
            .unwrap();
        }
        let conn2 = Arc::clone(&conn);
        let (done_tx, done_rx) = channel();
        let mut seen = 0;
        conn.consume(
            "q",
            "c1",
            1,
            Box::new(move |d| {
                conn2.ack(d.delivery_tag).unwrap();
                seen += 1;
                if seen == 10 {
                    done_tx.send(()).unwrap();
                }
            }),
        )
        .unwrap();
        done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        while broker.broker().queue_unacked("q") != Some(0) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn batched_backlog_dispatches_in_order_with_pipelined_acks() {
        // A pre-existing backlog arrives as DeliverBatch units; handler
        // acks coalesce into AckMulti frames and still drain the queue.
        let broker = InprocBroker::new();
        let conn = Arc::new(open(&broker));
        conn.request(&ClientRequest::QueueDeclare {
            queue: "bulk".into(),
            options: QueueOptions::default(),
        })
        .unwrap();
        for i in 0..40 {
            conn.request(&ClientRequest::Publish {
                exchange: "".into(),
                routing_key: "bulk".into(),
                body: Bytes::encode(&Value::I64(i)),
                props: Default::default(),
                mandatory: true,
            })
            .unwrap();
        }
        let conn2 = Arc::clone(&conn);
        let (done_tx, done_rx) = channel();
        let mut seen: Vec<i64> = Vec::new();
        conn.consume(
            "bulk",
            "c1",
            0,
            Box::new(move |d| {
                seen.push(d.body.decode().unwrap().as_i64().unwrap());
                conn2.ack(d.delivery_tag).unwrap();
                if seen.len() == 40 {
                    done_tx.send(seen.clone()).unwrap();
                }
            }),
        )
        .unwrap();
        let seen = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(seen, (0..40).collect::<Vec<i64>>(), "batch dispatch must preserve order");
        let deadline = Instant::now() + Duration::from_secs(2);
        while broker.broker().queue_unacked("bulk") != Some(0) {
            assert!(Instant::now() < deadline, "pipelined acks must drain the queue");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(broker.broker().delivery_index_len(), 0);
    }

    #[test]
    fn close_is_clean_and_idempotent() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        assert!(!conn.is_closed());
        conn.close();
        // A second connection still works (broker unaffected).
        let conn2 = open(&broker);
        assert!(conn2.request(&ClientRequest::Status).is_ok());
        conn2.close();
    }
}
