//! Client connection: request/reply correlation, consumer delivery
//! dispatch, heartbeats and transparent reconnection — all driven by a
//! hidden communication thread, kiwiPy's signature usability feature ("a
//! separate communication thread that the user never sees", maintaining
//! heartbeats "whilst the user code can be doing other things").
//!
//! Opened with a [`LinkFactory`], the connection *survives broker
//! outages*: link death (recv/send errors, two missed heartbeats) parks
//! in-flight requests, re-dials with capped exponential backoff + jitter,
//! replays the recorded topology (exchanges, queues, bindings) and
//! re-issues every consumer, so delivery handlers keep firing with no user
//! code — the paper's core robustness property. Unacked deliveries from
//! the dead link are redelivered by the broker's existing requeue path.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{JoinHandle, ThreadId};
use std::time::{Duration, Instant};

use crate::broker::protocol::{ClientRequest, Delivery, ServerMsg};
use crate::error::{Error, Result};
use crate::metrics::{Counter, Registry};
use crate::proputil::Rng;
use crate::transport::reconnect::{backoff_delay, LinkFactory, LinkSlot, TopologyJournal};
use crate::transport::Link;
use crate::wire::{Frame, FrameType};

/// Callback invoked on the communication thread for each delivery.
pub type DeliveryHandler = Box<dyn FnMut(Delivery) + Send>;

/// Connection tuning knobs.
#[derive(Clone, Debug)]
pub struct ConnectionConfig {
    /// Identity announced in `Hello` (shows up in broker logs).
    pub client_id: String,
    /// Heartbeat interval; 0 disables. Two missed intervals and the broker
    /// evicts us (requeueing our unacked messages); symmetrically we treat
    /// a silent broker as dead after two intervals.
    pub heartbeat_ms: u64,
    /// Default timeout for request/reply calls. Also bounds how long a
    /// request issued during an outage parks awaiting revival.
    pub request_timeout: Duration,
    /// Consecutive failed re-dial attempts before the connection gives up
    /// and closes for good. 0 disables reconnection even when a factory is
    /// available. Only meaningful for factory-opened connections.
    pub reconnect_max_retries: u32,
    /// Base reconnect backoff: attempt n sleeps `min(base·2ⁿ⁻¹, base·32)`
    /// plus uniform jitter in `[0, delay/2)`; the first re-dial is
    /// immediate.
    pub reconnect_backoff_ms: u64,
}

impl Default for ConnectionConfig {
    fn default() -> Self {
        ConnectionConfig {
            client_id: format!("kiwi-{}", std::process::id()),
            heartbeat_ms: 0,
            request_timeout: Duration::from_secs(10),
            reconnect_max_retries: 8,
            reconnect_backoff_ms: 250,
        }
    }
}

/// The ack-coalescing buffer, scoped to the thread that opened the window
/// (the communication thread dispatching a delivery batch). Acks from any
/// *other* thread bypass the window and go out immediately — a user thread
/// acking an old delivery must not have its ack parked behind unrelated
/// handlers.
struct AckBatch {
    owner: ThreadId,
    tags: Vec<u64>,
}

struct Shared {
    slot: LinkSlot,
    factory: Option<LinkFactory>,
    config: ConnectionConfig,
    next_req: AtomicU64,
    pending: Mutex<HashMap<u64, Sender<ServerMsg>>>,
    handlers: Mutex<HashMap<String, DeliveryHandler>>,
    /// Topology to replay on reconnect (recorded from acknowledged
    /// requests).
    journal: Mutex<TopologyJournal>,
    /// Permanently closed: retries exhausted or `close()` called.
    closed: AtomicBool,
    /// Instant of the last frame seen from the broker (liveness).
    last_server_frame: Mutex<Instant>,
    /// Ack pipeline: `Some` while a delivery batch is being dispatched on
    /// the communication thread; acks issued *by that thread* in that
    /// window buffer here and go out as one `AckMulti` frame at the end of
    /// the batch.
    ack_buffer: Mutex<Option<AckBatch>>,
    /// Delivery tags handed to handlers on the *current* link and not yet
    /// resolved. Maintained only on reconnecting connections: an
    /// ack/nack/reject for a tag outside this set is *stale* — delivered
    /// on a link that has since died. The broker already requeued it, so
    /// the frame must not be sent (and could not safely be matched by
    /// value anyway; the broker's boot-origin tag counters guarantee a
    /// restarted broker never reissues an old boot's tag values, see
    /// `broker::shard::boot_tag_origin`).
    live_tags: Option<Mutex<HashSet<u64>>>,
    /// When the current link was installed (flap detection: a link that
    /// dies right after install skips the free immediate re-dial).
    last_install: Mutex<Instant>,
    /// Publish-credit window granted by the broker. `None` until the first
    /// `Credit` frame on the current link — an uncredited link publishes
    /// unlimited, so connections to brokers that never grant (credit
    /// disabled, older broker) behave exactly as before. Publishers park
    /// on `credit_cv` (bounded by their request timeout) when the window
    /// runs empty; the reader thread's grant wakes them.
    credit: Mutex<Option<u64>>,
    credit_cv: Condvar,
    metrics: Registry,
    reconnects: Arc<Counter>,
    replayed_consumers: Arc<Counter>,
}

impl Shared {
    fn reconnect_enabled(&self) -> bool {
        self.factory.is_some() && self.config.reconnect_max_retries > 0
    }

    fn mark_closed(&self) {
        if !self.closed.swap(true, Ordering::SeqCst) {
            self.slot.close();
            self.fail_pending();
            // Publishers parked on credit must see `closed` promptly.
            self.credit_cv.notify_all();
        }
    }

    /// Fail every in-flight request waiter: dropping the senders wakes the
    /// receivers, which either retry (reconnecting connection, deadline
    /// permitting) or surface `Closed`.
    fn fail_pending(&self) {
        self.pending.lock().unwrap().clear();
    }

    /// Install a broker credit grant and wake parked publishers.
    fn grant_credit(&self, n: u64) {
        *self.credit.lock().unwrap() = Some(n);
        self.credit_cv.notify_all();
    }

    /// Forget the dead link's credit window. The revived broker session
    /// re-grants right after `Hello`; until then the link is uncredited
    /// (unlimited), matching a fresh connection.
    fn reset_credit(&self) {
        *self.credit.lock().unwrap() = None;
        self.credit_cv.notify_all();
    }

    /// Take one publish credit, parking (bounded by `deadline`) while the
    /// broker's window is empty — the client half of channel flow control.
    fn acquire_publish_credit(&self, deadline: Instant) -> Result<()> {
        let mut credit = self.credit.lock().unwrap();
        loop {
            if self.closed.load(Ordering::Relaxed) {
                return Err(Error::Closed("connection closed".into()));
            }
            match *credit {
                None => return Ok(()), // uncredited link: unlimited
                Some(n) if n > 0 => {
                    *credit = Some(n - 1);
                    return Ok(());
                }
                Some(_) => {
                    let wait = deadline.saturating_duration_since(Instant::now());
                    if wait.is_zero() {
                        return Err(Error::Timeout(
                            "publish blocked on broker credit".into(),
                        ));
                    }
                    credit = self.credit_cv.wait_timeout(credit, wait).unwrap().0;
                }
            }
        }
    }

    /// React to a send failure on the link stamped `epoch`: flag the
    /// outage for the communication thread to repair, or — without
    /// reconnection — poison the connection as before.
    fn link_failed(&self, epoch: u64) {
        if self.reconnect_enabled() {
            self.slot.report_failure(epoch);
        } else {
            self.mark_closed();
        }
    }

    /// Fire-and-forget send: no reply waited for (the broker's Ok is
    /// dropped by the reader when no waiter is found). Fails fast during
    /// an outage — callers on the ack path must not block.
    fn send_noreply(&self, req: &ClientRequest) -> Result<()> {
        if self.closed.load(Ordering::Relaxed) {
            return Err(Error::Closed("connection closed".into()));
        }
        if matches!(req, ClientRequest::Publish { .. }) {
            self.acquire_publish_credit(Instant::now() + self.config.request_timeout)?;
        }
        let (link, epoch) = self.slot.current()?;
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        link.send(&req.to_frame(req_id)).map_err(|e| {
            self.link_failed(epoch);
            e
        })
    }

    /// Record tags about to be dispatched on the current link.
    fn track_deliveries(&self, tags: impl Iterator<Item = u64>) {
        if let Some(live) = &self.live_tags {
            live.lock().unwrap().extend(tags);
        }
    }

    /// Resolve a tag (ack/nack/reject path). False = the tag is stale
    /// (pre-outage, or already resolved) and must not go on the wire.
    fn resolve_tag(&self, tag: u64) -> bool {
        match &self.live_tags {
            Some(live) => live.lock().unwrap().remove(&tag),
            None => true,
        }
    }

    /// Every outstanding tag died with its link.
    fn clear_live_tags(&self) {
        if let Some(live) = &self.live_tags {
            live.lock().unwrap().clear();
        }
    }

    /// Close the window and flush everything buffered as a single frame.
    fn flush_ack_window(&self) {
        let batch = self.ack_buffer.lock().unwrap().take();
        let Some(batch) = batch else { return };
        let req = match batch.tags.len() {
            0 => return,
            1 => ClientRequest::Ack { delivery_tag: batch.tags[0] },
            _ => ClientRequest::AckMulti { delivery_tags: batch.tags },
        };
        self.send_noreply(&req).ok();
    }
}

/// RAII handle for the ack-coalescing window: flushes on drop, so the
/// window closes — and buffered acks still go out — even if a delivery
/// handler panics mid-batch.
struct AckWindow {
    shared: Arc<Shared>,
}

/// Open the ack-coalescing window (communication thread only); only acks
/// issued by the opening thread coalesce into it.
fn open_ack_window(shared: &Arc<Shared>) -> AckWindow {
    *shared.ack_buffer.lock().unwrap() =
        Some(AckBatch { owner: std::thread::current().id(), tags: Vec::new() });
    AckWindow { shared: Arc::clone(shared) }
}

impl Drop for AckWindow {
    fn drop(&mut self) {
        self.shared.flush_ack_window();
    }
}

/// Does this request mutate broker topology (and so belong in the
/// reconnect journal once acknowledged)?
fn is_topology(req: &ClientRequest) -> bool {
    matches!(
        req,
        ClientRequest::ExchangeDeclare { .. }
            | ClientRequest::QueueDeclare { .. }
            | ClientRequest::Bind { .. }
            | ClientRequest::Unbind { .. }
            | ClientRequest::QueueDelete { .. }
    )
}

/// A client connection to a broker (TCP or in-process — any [`Link`]).
pub struct Connection {
    shared: Arc<Shared>,
    config: ConnectionConfig,
    reader: Mutex<Option<JoinHandle<()>>>,
    heartbeater: Mutex<Option<JoinHandle<()>>>,
}

impl Connection {
    /// Open a connection over an existing `link`. Without a factory there
    /// is nothing to re-dial: any link failure permanently closes the
    /// connection (use [`Connection::open_with_factory`] for resilience).
    pub fn open(link: Arc<dyn Link>, config: ConnectionConfig) -> Result<Self> {
        Self::open_inner(link, None, config)
    }

    /// Open a *reconnecting* connection: `factory` dials the broker, and
    /// re-dials it whenever the link dies, replaying topology and
    /// consumers so the outage is invisible to user code (bounded by
    /// `reconnect_max_retries`).
    pub fn open_with_factory(factory: LinkFactory, config: ConnectionConfig) -> Result<Self> {
        let link = factory()?;
        Self::open_inner(link, Some(factory), config)
    }

    fn open_inner(
        link: Arc<dyn Link>,
        factory: Option<LinkFactory>,
        config: ConnectionConfig,
    ) -> Result<Self> {
        let metrics = Registry::new();
        let reconnectable = factory.is_some() && config.reconnect_max_retries > 0;
        let shared = Arc::new(Shared {
            slot: LinkSlot::new(link),
            factory,
            config: config.clone(),
            next_req: AtomicU64::new(1),
            pending: Mutex::new(HashMap::new()),
            handlers: Mutex::new(HashMap::new()),
            journal: Mutex::new(TopologyJournal::default()),
            closed: AtomicBool::new(false),
            last_server_frame: Mutex::new(Instant::now()),
            ack_buffer: Mutex::new(None),
            live_tags: reconnectable.then(|| Mutex::new(HashSet::new())),
            last_install: Mutex::new(Instant::now()),
            credit: Mutex::new(None),
            credit_cv: Condvar::new(),
            reconnects: metrics.counter("client.reconnects_total"),
            replayed_consumers: metrics.counter("client.replayed_consumers_total"),
            metrics,
        });

        let reader = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("kiwi-comm".into())
                .spawn(move || reader_loop(shared))
                .expect("spawn communication thread")
        };

        let heartbeater = if config.heartbeat_ms > 0 {
            let shared = Arc::clone(&shared);
            let interval = Duration::from_millis((config.heartbeat_ms / 2).max(1));
            Some(
                std::thread::Builder::new()
                    .name("kiwi-heartbeat".into())
                    .spawn(move || {
                        while !shared.closed.load(Ordering::Relaxed) {
                            std::thread::sleep(interval);
                            // During an outage the slot is Down: skip the
                            // beat, the comm thread is re-dialing.
                            if let Ok((link, epoch)) = shared.slot.current() {
                                if link.send(&Frame::heartbeat()).is_err() {
                                    shared.link_failed(epoch);
                                }
                            }
                        }
                    })
                    .expect("spawn heartbeater"),
            )
        } else {
            None
        };

        let conn = Connection {
            shared,
            config: config.clone(),
            reader: Mutex::new(Some(reader)),
            heartbeater: Mutex::new(heartbeater),
        };
        conn.request(&ClientRequest::Hello {
            client_id: config.client_id.clone(),
            heartbeat_ms: config.heartbeat_ms,
        })?;
        Ok(conn)
    }

    /// Client-side metrics: `client.reconnects_total`,
    /// `client.replayed_consumers_total`.
    pub fn metrics(&self) -> &Registry {
        &self.shared.metrics
    }

    /// Send a request and wait for the broker's reply.
    pub fn request(&self, req: &ClientRequest) -> Result<crate::wire::Value> {
        self.request_timeout(req, self.config.request_timeout)
    }

    /// Send a request and wait up to `timeout`. On a reconnecting
    /// connection a request that hits an outage *parks* and is re-sent
    /// after revival (still bounded by `timeout`) instead of failing with
    /// `Closed`; a request whose link dies mid-flight is retried the same
    /// way, so delivery is at-least-once across an outage.
    pub fn request_timeout(
        &self,
        req: &ClientRequest,
        timeout: Duration,
    ) -> Result<crate::wire::Value> {
        let deadline = Instant::now() + timeout;
        loop {
            if self.shared.closed.load(Ordering::Relaxed) {
                return Err(Error::Closed("connection closed".into()));
            }
            let (link, epoch) = if self.shared.reconnect_enabled() {
                self.shared.slot.await_up(deadline)?
            } else {
                self.shared.slot.current()?
            };
            // Credit gate: a broker-granted publish window throttles this
            // publisher here, before the frame is even built, bounded by
            // the same deadline as the request itself.
            if matches!(req, ClientRequest::Publish { .. }) {
                self.shared.acquire_publish_credit(deadline)?;
            }
            let req_id = self.shared.next_req.fetch_add(1, Ordering::Relaxed);
            let (tx, rx): (Sender<ServerMsg>, Receiver<ServerMsg>) = channel();
            self.shared.pending.lock().unwrap().insert(req_id, tx);
            if let Err(e) = self.shared.link_send(&link, epoch, &req.to_frame(req_id), req_id) {
                if self.shared.reconnect_enabled() && Instant::now() < deadline {
                    continue; // park on the next await_up
                }
                return Err(e);
            }
            let wait = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(wait) {
                Ok(ServerMsg::Ok { reply, .. }) => {
                    if is_topology(req) {
                        self.shared.journal.lock().unwrap().observe(req);
                    }
                    return Ok(reply);
                }
                Ok(ServerMsg::Err { code, message, .. }) => {
                    return Err(decode_remote_error(&code, message))
                }
                Ok(other) => return Err(Error::Wire(format!("unexpected reply {other:?}"))),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    self.shared.pending.lock().unwrap().remove(&req_id);
                    return Err(Error::Timeout(format!("request {req_id}")));
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    // The link died with our request in flight (pending map
                    // cleared by the outage path). Retry after revival.
                    if self.shared.reconnect_enabled()
                        && !self.shared.closed.load(Ordering::Relaxed)
                        && Instant::now() < deadline
                    {
                        continue;
                    }
                    return Err(Error::Closed("connection lost".into()));
                }
            }
        }
    }

    /// Fire-and-forget request (acks on the hot path): no reply waited for;
    /// the broker's Ok is dropped by the reader when no waiter is found.
    pub fn send_noreply(&self, req: &ClientRequest) -> Result<()> {
        self.shared.send_noreply(req)
    }

    /// Start consuming `queue`: registers `handler` (invoked on the
    /// communication thread) and issues `Consume`. A tag already held by a
    /// live consumer on this connection is refused up front — registering
    /// first and rolling back on a broker error must never clobber (or
    /// tear down) a healthy subscription.
    pub fn consume(
        &self,
        queue: &str,
        consumer_tag: &str,
        prefetch: u32,
        handler: DeliveryHandler,
    ) -> Result<()> {
        {
            let mut handlers = self.shared.handlers.lock().unwrap();
            if handlers.contains_key(consumer_tag) {
                return Err(Error::DuplicateSubscriber(format!(
                    "consumer tag '{consumer_tag}' already registered on this connection"
                )));
            }
            handlers.insert(consumer_tag.to_string(), handler);
        }
        let res = self.request(&ClientRequest::Consume {
            queue: queue.to_string(),
            consumer_tag: consumer_tag.to_string(),
            prefetch,
        });
        match res {
            Ok(_) => {
                let mut journal = self.shared.journal.lock().unwrap();
                journal.record_consumer(consumer_tag, queue, prefetch);
                Ok(())
            }
            Err(e) => {
                // Remove exactly what this call inserted; the guard above
                // means the tag cannot belong to anyone else.
                self.shared.handlers.lock().unwrap().remove(consumer_tag);
                Err(e)
            }
        }
    }

    /// Attach to a stream queue as a member of `group` (created on first
    /// attach): registers `handler` and issues `StreamConsume`. Deliveries
    /// carry their log offset (`Delivery::offset`); acking advances the
    /// group's committed cursor instead of deleting the entry. `offset`
    /// seeks the group before attaching — honored only while the group has
    /// no other members. On reconnect the subscription is replayed with no
    /// seek, resuming from the group's committed position.
    pub fn stream_consume(
        &self,
        queue: &str,
        consumer_tag: &str,
        group: &str,
        prefetch: u32,
        offset: Option<u64>,
        handler: DeliveryHandler,
    ) -> Result<()> {
        {
            let mut handlers = self.shared.handlers.lock().unwrap();
            if handlers.contains_key(consumer_tag) {
                return Err(Error::DuplicateSubscriber(format!(
                    "consumer tag '{consumer_tag}' already registered on this connection"
                )));
            }
            handlers.insert(consumer_tag.to_string(), handler);
        }
        let res = self.request(&ClientRequest::StreamConsume {
            queue: queue.to_string(),
            consumer_tag: consumer_tag.to_string(),
            group: group.to_string(),
            prefetch,
            offset,
        });
        match res {
            Ok(_) => {
                let mut journal = self.shared.journal.lock().unwrap();
                journal.record_stream_consumer(consumer_tag, queue, group, prefetch);
                Ok(())
            }
            Err(e) => {
                self.shared.handlers.lock().unwrap().remove(consumer_tag);
                Err(e)
            }
        }
    }

    /// Move a stream group's committed cursor to just past `offset`.
    /// Forward commits skip entries without reading them; a backward
    /// commit rewinds the group and replays from there. Returns the
    /// group's committed cursor after the move.
    pub fn stream_commit(&self, queue: &str, group: &str, offset: u64) -> Result<u64> {
        let reply = self.request(&ClientRequest::StreamCommit {
            queue: queue.to_string(),
            group: group.to_string(),
            offset,
        })?;
        reply.get_u64("committed")
    }

    /// Stop consuming.
    pub fn cancel(&self, consumer_tag: &str) -> Result<()> {
        self.request(&ClientRequest::Cancel { consumer_tag: consumer_tag.to_string() })?;
        self.shared.handlers.lock().unwrap().remove(consumer_tag);
        self.shared.journal.lock().unwrap().remove_consumer(consumer_tag);
        Ok(())
    }

    /// Acknowledge a delivery (fire-and-forget). Acks issued *by the
    /// communication thread* while it is dispatching a delivery batch are
    /// pipelined: they buffer and leave as one `AckMulti` frame when the
    /// batch ends. Acks from any other thread go out immediately — they
    /// must not wait on unrelated handlers finishing the batch.
    pub fn ack(&self, delivery_tag: u64) -> Result<()> {
        if self.shared.closed.load(Ordering::Relaxed) {
            return Err(Error::Closed("connection closed".into()));
        }
        if !self.shared.resolve_tag(delivery_tag) {
            // Pre-outage delivery: the broker already requeued it, and the
            // tag value may since name a different message. Dropping the
            // ack is the safe outcome — the redelivery carries a new tag.
            log::debug!("connection: dropping stale ack for tag {delivery_tag}");
            return Ok(());
        }
        {
            let mut buf = self.shared.ack_buffer.lock().unwrap();
            if let Some(batch) = buf.as_mut() {
                if batch.owner == std::thread::current().id() {
                    batch.tags.push(delivery_tag);
                    return Ok(());
                }
            }
        }
        self.send_noreply(&ClientRequest::Ack { delivery_tag })
    }

    /// Reject a delivery, optionally requeueing (fire-and-forget). With
    /// `requeue = false` — or when the message has hit its queue's
    /// `max_delivery` cap — the broker dead-letters it instead of
    /// redelivering.
    pub fn nack(&self, delivery_tag: u64, requeue: bool) -> Result<()> {
        if !self.shared.resolve_tag(delivery_tag) {
            log::debug!("connection: dropping stale nack for tag {delivery_tag}");
            return Ok(());
        }
        self.send_noreply(&ClientRequest::Nack { delivery_tag, requeue })
    }

    /// Negative-acknowledge many deliveries in one frame.
    pub fn nack_multi(&self, delivery_tags: Vec<u64>, requeue: bool) -> Result<()> {
        let delivery_tags: Vec<u64> =
            delivery_tags.into_iter().filter(|t| self.shared.resolve_tag(*t)).collect();
        if delivery_tags.is_empty() {
            return Ok(());
        }
        self.send_noreply(&ClientRequest::NackMulti { delivery_tags, requeue })
    }

    /// AMQP `basic.reject`: refuse a single delivery (fire-and-forget).
    /// Same broker semantics as [`Connection::nack`].
    pub fn reject(&self, delivery_tag: u64, requeue: bool) -> Result<()> {
        if !self.shared.resolve_tag(delivery_tag) {
            log::debug!("connection: dropping stale reject for tag {delivery_tag}");
            return Ok(());
        }
        self.send_noreply(&ClientRequest::Reject { delivery_tag, requeue })
    }

    /// True when the connection is permanently closed (explicit `close()`
    /// or reconnect retries exhausted). False during an outage the
    /// connection is still trying to repair.
    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Relaxed)
    }

    /// Graceful close: `Close` to the broker, stop threads, clear delivery
    /// handlers (breaking any `Arc<Connection>` cycles closures hold).
    /// Idempotent; callable from any thread except the communication
    /// thread itself. Called mid-outage it aborts any backoff sleep and
    /// terminates promptly.
    pub fn close(&self) {
        if !self.shared.closed.load(Ordering::Relaxed) {
            self.request_timeout(&ClientRequest::Close, Duration::from_millis(500)).ok();
        }
        self.shared.mark_closed();
        if let Some(h) = self.reader.lock().unwrap().take() {
            h.join().ok();
        }
        if let Some(h) = self.heartbeater.lock().unwrap().take() {
            h.join().ok();
        }
        self.shared.handlers.lock().unwrap().clear();
    }
}

impl Drop for Connection {
    fn drop(&mut self) {
        self.close();
    }
}

impl Shared {
    /// Send one request frame, cleaning up the pending entry and flagging
    /// the outage on failure.
    fn link_send(
        &self,
        link: &Arc<dyn Link>,
        epoch: u64,
        frame: &Frame,
        req_id: u64,
    ) -> Result<()> {
        if let Err(e) = link.send(frame) {
            self.pending.lock().unwrap().remove(&req_id);
            self.link_failed(epoch);
            return Err(e);
        }
        Ok(())
    }
}

fn decode_remote_error(code: &str, message: String) -> Error {
    match code {
        "unroutable" => Error::UnroutableMessage(message),
        "duplicate-subscriber" => Error::DuplicateSubscriber(message),
        "timeout" => Error::Timeout(message),
        "remote-exception" => Error::RemoteException(message),
        _ => Error::Broker(message),
    }
}

/// Dispatch deliveries to their handlers with the ack window open: handler
/// acks coalesce into one `AckMulti` frame sent when the batch is done.
/// The guard flushes on drop (panic-safe).
fn dispatch_batch(shared: &Arc<Shared>, deliveries: Vec<Delivery>) {
    if deliveries.is_empty() {
        return;
    }
    shared.track_deliveries(deliveries.iter().map(|d| d.delivery_tag));
    let window = open_ack_window(shared);
    {
        let mut handlers = shared.handlers.lock().unwrap();
        for d in deliveries {
            if let Some(h) = handlers.get_mut(&d.consumer_tag) {
                h(d);
            } else {
                log::warn!("connection: delivery for unknown consumer '{}'", d.consumer_tag);
            }
        }
    }
    drop(window);
}

/// Why the pump stopped reading the current link.
enum PumpExit {
    /// Graceful: `closed` was set.
    Closed,
    /// The link is dead (recv error, goodbye, corrupt frame, heartbeat
    /// expiry) — reconnect if we can.
    LinkDead,
}

/// The hidden communication thread: demultiplexes replies, deliveries and
/// server heartbeats on the current link; when the link dies, drives
/// recovery (backoff → re-dial → topology replay) and resumes.
fn reader_loop(shared: Arc<Shared>) {
    loop {
        if shared.closed.load(Ordering::Relaxed) {
            break;
        }
        let (link, epoch) = match shared.slot.current() {
            Ok(x) => x,
            Err(_) => {
                // Closed terminally, or a sender flagged the link Down
                // before we noticed: fall through to recovery.
                if shared.slot.is_closed() {
                    break;
                }
                shared.fail_pending();
                shared.clear_live_tags();
                if !(shared.reconnect_enabled() && recover(&shared)) {
                    shared.mark_closed();
                    break;
                }
                continue;
            }
        };
        match pump_link(&shared, &link) {
            PumpExit::Closed => break,
            PumpExit::LinkDead => {
                shared.slot.report_failure(epoch);
                // Wake parked requesters; deadline permitting they re-send
                // after revival. Outstanding delivery tags died with the
                // link — the broker requeues them, so late acks are stale.
                shared.fail_pending();
                shared.clear_live_tags();
                shared.reset_credit();
                if !(shared.reconnect_enabled() && recover(&shared)) {
                    shared.mark_closed();
                    break;
                }
            }
        }
    }
}

/// Read frames off one link until it dies or the connection closes.
fn pump_link(shared: &Arc<Shared>, link: &Arc<dyn Link>) -> PumpExit {
    let heartbeat_ms = shared.config.heartbeat_ms;
    let poll =
        Duration::from_millis(if heartbeat_ms > 0 { (heartbeat_ms / 2).max(1) } else { 200 });
    loop {
        if shared.closed.load(Ordering::Relaxed) {
            return PumpExit::Closed;
        }
        match link.recv_timeout(poll) {
            Ok(frame) => {
                *shared.last_server_frame.lock().unwrap() = Instant::now();
                match frame.frame_type {
                    FrameType::Heartbeat => {}
                    FrameType::Goodbye => {
                        log::debug!("connection: broker said goodbye");
                        return PumpExit::LinkDead;
                    }
                    FrameType::Data => match ServerMsg::from_frame(&frame) {
                        Ok(ServerMsg::Deliver(d)) => {
                            shared.track_deliveries(std::iter::once(d.delivery_tag));
                            let mut handlers = shared.handlers.lock().unwrap();
                            if let Some(h) = handlers.get_mut(&d.consumer_tag) {
                                h(d);
                            } else {
                                log::warn!(
                                    "connection: delivery for unknown consumer '{}'",
                                    d.consumer_tag
                                );
                            }
                        }
                        Ok(ServerMsg::DeliverBatch(ds)) => dispatch_batch(shared, ds),
                        Ok(ServerMsg::CancelConsumer { consumer_tag }) => {
                            shared.handlers.lock().unwrap().remove(&consumer_tag);
                            shared.journal.lock().unwrap().remove_consumer(&consumer_tag);
                        }
                        Ok(ServerMsg::Credit { channel_credit }) => {
                            shared.grant_credit(u64::from(channel_credit));
                        }
                        Ok(msg @ (ServerMsg::Ok { .. } | ServerMsg::Err { .. })) => {
                            let req_id = match &msg {
                                ServerMsg::Ok { req_id, .. } | ServerMsg::Err { req_id, .. } => {
                                    *req_id
                                }
                                _ => unreachable!(),
                            };
                            if let Some(tx) = shared.pending.lock().unwrap().remove(&req_id) {
                                tx.send(msg).ok();
                            }
                            // No waiter = fire-and-forget request; drop.
                        }
                        Err(e) => {
                            log::warn!("connection: bad frame from broker: {e}");
                            return PumpExit::LinkDead;
                        }
                    },
                }
            }
            Err(Error::Timeout(_)) => {
                // Detect a dead broker: two missed heartbeat intervals.
                if heartbeat_ms > 0 {
                    let last = *shared.last_server_frame.lock().unwrap();
                    if last.elapsed().as_millis() as u64 > 2 * heartbeat_ms {
                        log::warn!("connection: broker silent for 2 heartbeat intervals");
                        return PumpExit::LinkDead;
                    }
                }
            }
            Err(_) => return PumpExit::LinkDead,
        }
    }
}

/// Drive the reconnect loop: backoff, re-dial, replay. Returns true once a
/// replayed link is installed, false when retries are exhausted or the
/// connection closed mid-recovery. Runs on the communication thread.
fn recover(shared: &Arc<Shared>) -> bool {
    let Some(factory) = shared.factory.as_ref() else { return false };
    let max_retries = shared.config.reconnect_max_retries.max(1);
    let base_ms = shared.config.reconnect_backoff_ms;
    let rng = Rng::new(jitter_seed());
    // Flap guard: a link that died almost immediately after install means
    // a crash-looping (or Goodbye-spamming) broker — skip the free
    // immediate re-dial so each flap cycle still pays a backoff, instead
    // of hammering the broker in a tight dial+replay loop.
    let flap_window = Duration::from_millis(base_ms.max(1).saturating_mul(2));
    let flapping = shared.last_install.lock().unwrap().elapsed() < flap_window;
    let mut attempt: u32 = u32::from(flapping);
    loop {
        if shared.closed.load(Ordering::Relaxed) || shared.slot.is_closed() {
            return false;
        }
        let delay = backoff_delay(attempt, base_ms, rng.next_u64());
        if !delay.is_zero() && !shared.slot.sleep_unless_closed(delay) {
            return false;
        }
        let failure = match factory() {
            Ok(link) => match replay_topology(shared, &link) {
                Ok(buffered) => {
                    *shared.last_server_frame.lock().unwrap() = Instant::now();
                    *shared.last_install.lock().unwrap() = Instant::now();
                    let Some(epoch) = shared.slot.install(Arc::clone(&link)) else {
                        // close() won the race during the dial/replay; the
                        // fresh link (and its broker session) was severed.
                        return false;
                    };
                    shared.reconnects.inc();
                    log::info!(
                        "connection: reconnected to {} (epoch {epoch}, attempt {})",
                        link.peer(),
                        attempt + 1
                    );
                    // Deliveries that raced the replay tail dispatch now,
                    // through the normal batched path.
                    dispatch_batch(shared, buffered);
                    return true;
                }
                Err(e) => {
                    link.close();
                    e
                }
            },
            Err(e) => e,
        };
        attempt += 1;
        if attempt >= max_retries {
            log::error!("connection: giving up after {attempt} reconnect attempts: {failure}");
            return false;
        }
        log::warn!("connection: reconnect attempt {attempt}/{max_retries} failed: {failure}");
    }
}

fn jitter_seed() -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64)
        .unwrap_or(1);
    nanos ^ ((std::process::id() as u64) << 32)
}

/// Re-teach a fresh link everything the dead one knew: `Hello`, then the
/// journal (exchanges → queues → bindings), then every consumer whose
/// handler is still registered. Runs request/reply synchronously on the
/// new link *before* it is installed, so user traffic stays parked until
/// the broker is fully revived. Deliveries that start arriving once
/// consumers re-register are buffered and returned for normal dispatch.
fn replay_topology(shared: &Arc<Shared>, link: &Arc<dyn Link>) -> Result<Vec<Delivery>> {
    let mut buffered = Vec::new();
    sync_request(
        shared,
        link,
        &ClientRequest::Hello {
            client_id: shared.config.client_id.clone(),
            heartbeat_ms: shared.config.heartbeat_ms,
        },
        &mut buffered,
    )?;
    let (requests, consumers) = {
        let journal = shared.journal.lock().unwrap();
        (journal.replay_requests(), journal.consumers())
    };
    for req in &requests {
        sync_request(shared, link, req, &mut buffered)?;
    }
    let mut replayed = 0u64;
    for c in &consumers {
        if !shared.handlers.lock().unwrap().contains_key(&c.consumer_tag) {
            continue; // handler vanished (cancelled mid-outage)
        }
        let req = match &c.group {
            // Stream members re-attach with no seek: the broker-side
            // group cursor (possibly shared with surviving members) is
            // the resume position.
            Some(group) => ClientRequest::StreamConsume {
                queue: c.queue.clone(),
                consumer_tag: c.consumer_tag.clone(),
                group: group.clone(),
                prefetch: c.prefetch,
                offset: None,
            },
            None => ClientRequest::Consume {
                queue: c.queue.clone(),
                consumer_tag: c.consumer_tag.clone(),
                prefetch: c.prefetch,
            },
        };
        sync_request(shared, link, &req, &mut buffered)?;
        replayed += 1;
    }
    shared.replayed_consumers.add(replayed);
    Ok(buffered)
}

/// One synchronous request/reply exchange on a not-yet-installed link.
/// Deliveries arriving mid-replay (consumers re-registered earlier in the
/// same replay) are buffered, not dispatched — handlers must not run until
/// the link is installed and sends work again.
fn sync_request(
    shared: &Arc<Shared>,
    link: &Arc<dyn Link>,
    req: &ClientRequest,
    buffered: &mut Vec<Delivery>,
) -> Result<crate::wire::Value> {
    let req_id = shared.next_req.fetch_add(1, Ordering::Relaxed);
    link.send(&req.to_frame(req_id))?;
    let deadline = Instant::now() + shared.config.request_timeout;
    loop {
        if shared.closed.load(Ordering::Relaxed) {
            return Err(Error::Closed("connection closed".into()));
        }
        let now = Instant::now();
        if now >= deadline {
            return Err(Error::Timeout(format!("replay request {req_id}")));
        }
        let wait = (deadline - now).min(Duration::from_millis(200));
        match link.recv_timeout(wait) {
            Ok(frame) => match frame.frame_type {
                FrameType::Heartbeat => {}
                FrameType::Goodbye => {
                    return Err(Error::Closed("broker said goodbye during replay".into()))
                }
                FrameType::Data => match ServerMsg::from_frame(&frame)? {
                    ServerMsg::Ok { req_id: id, reply } if id == req_id => return Ok(reply),
                    ServerMsg::Err { req_id: id, code, message } if id == req_id => {
                        return Err(decode_remote_error(&code, message))
                    }
                    ServerMsg::Deliver(d) => buffered.push(d),
                    ServerMsg::DeliverBatch(ds) => buffered.extend(ds),
                    ServerMsg::CancelConsumer { consumer_tag } => {
                        shared.handlers.lock().unwrap().remove(&consumer_tag);
                        shared.journal.lock().unwrap().remove_consumer(&consumer_tag);
                    }
                    ServerMsg::Credit { channel_credit } => {
                        shared.grant_credit(u64::from(channel_credit))
                    }
                    // A reply to some pre-outage request: its waiter was
                    // already failed (and will retry); drop it.
                    ServerMsg::Ok { .. } | ServerMsg::Err { .. } => {}
                },
            },
            Err(Error::Timeout(_)) => {}
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::QueueOptions;
    use crate::broker::InprocBroker;
    use crate::wire::{Bytes, Value};

    fn open(broker: &InprocBroker) -> Connection {
        Connection::open(broker.connect(), ConnectionConfig::default()).unwrap()
    }

    fn declare(conn: &Connection, queue: &str) {
        conn.request(&ClientRequest::QueueDeclare {
            queue: queue.into(),
            options: QueueOptions::default(),
        })
        .unwrap();
    }

    fn publish(conn: &Connection, queue: &str, v: Value) {
        conn.request(&ClientRequest::Publish {
            exchange: "".into(),
            routing_key: queue.into(),
            body: Bytes::encode(&v),
            props: Default::default(),
            mandatory: true,
        })
        .unwrap();
    }

    #[test]
    fn hello_and_declare() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        let reply = conn
            .request(&ClientRequest::QueueDeclare {
                queue: "q".into(),
                options: QueueOptions::default(),
            })
            .unwrap();
        assert_eq!(reply.get_str("queue").unwrap(), "q");
        conn.close();
    }

    #[test]
    fn consume_dispatches_to_handler() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        declare(&conn, "q");
        let (tx, rx) = channel();
        conn.consume(
            "q",
            "c1",
            0,
            Box::new(move |d| {
                tx.send(d.body.decode().unwrap()).unwrap();
            }),
        )
        .unwrap();
        publish(&conn, "q", Value::str("hi"));
        assert_eq!(rx.recv_timeout(Duration::from_secs(2)).unwrap(), Value::str("hi"));
        conn.close();
    }

    #[test]
    fn publisher_blocks_on_credit_and_resumes_after_regrant() {
        use crate::broker::core::{BrokerConfig, BrokerHandle};
        use crate::broker::persistence::{NoopPersister, RecoveredState};
        // A one-byte page-out threshold makes any backlog "pressure", and
        // a 4-credit window stalls the publisher after four publishes.
        let broker = InprocBroker::with_broker(BrokerHandle::with_config(
            Box::new(NoopPersister),
            RecoveredState::default(),
            BrokerConfig { page_out_threshold: 1, publish_credit: 4, ..Default::default() },
        ));
        let conn = open(&broker);
        declare(&conn, "q");
        for i in 0..4 {
            publish(&conn, "q", Value::I64(i));
        }
        // Window exhausted against a backlogged queue: the fifth publish
        // must park on credit and time out, not reach the broker.
        let err = conn
            .request_timeout(
                &ClientRequest::Publish {
                    exchange: "".into(),
                    routing_key: "q".into(),
                    body: Bytes::encode(&Value::I64(99)),
                    props: Default::default(),
                    mandatory: true,
                },
                Duration::from_millis(200),
            )
            .unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "expected credit stall, got {err:?}");
        assert_eq!(broker.broker().queue_depth("q"), Some(4));
        assert!(broker.broker().metrics().counter("broker.credit_stalls_total").get() >= 1);
        // Drain the backlog; the sweep notices the low-water mark and
        // re-grants, after which the parked publisher resumes by itself.
        conn.request(&ClientRequest::QueuePurge { queue: "q".into() }).unwrap();
        broker.broker().sweep();
        publish(&conn, "q", Value::I64(100));
        assert_eq!(broker.broker().queue_depth("q"), Some(1));
        conn.close();
    }

    #[test]
    fn broker_error_becomes_typed_error() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        let err = conn
            .request(&ClientRequest::Publish {
                exchange: "".into(),
                routing_key: "missing".into(),
                body: Bytes::encode(&Value::Null),
                props: Default::default(),
                mandatory: true,
            })
            .unwrap_err();
        assert!(matches!(err, Error::UnroutableMessage(_)));
        conn.close();
    }

    #[test]
    fn concurrent_requests_from_many_threads() {
        let broker = InprocBroker::new();
        let conn = Arc::new(open(&broker));
        declare(&conn, "q");
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let conn = Arc::clone(&conn);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        publish(&conn, "q", Value::I64(t * 1000 + i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(broker.broker().queue_depth("q"), Some(400));
    }

    #[test]
    fn ack_fire_and_forget_drains_queue() {
        let broker = InprocBroker::new();
        let conn = Arc::new(open(&broker));
        declare(&conn, "q");
        for i in 0..10 {
            publish(&conn, "q", Value::I64(i));
        }
        let conn2 = Arc::clone(&conn);
        let (done_tx, done_rx) = channel();
        let mut seen = 0;
        conn.consume(
            "q",
            "c1",
            1,
            Box::new(move |d| {
                conn2.ack(d.delivery_tag).unwrap();
                seen += 1;
                if seen == 10 {
                    done_tx.send(()).unwrap();
                }
            }),
        )
        .unwrap();
        done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        while broker.broker().queue_unacked("q") != Some(0) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn batched_backlog_dispatches_in_order_with_pipelined_acks() {
        // A pre-existing backlog arrives as DeliverBatch units; handler
        // acks coalesce into AckMulti frames and still drain the queue.
        let broker = InprocBroker::new();
        let conn = Arc::new(open(&broker));
        declare(&conn, "bulk");
        for i in 0..40 {
            publish(&conn, "bulk", Value::I64(i));
        }
        let conn2 = Arc::clone(&conn);
        let (done_tx, done_rx) = channel();
        let mut seen: Vec<i64> = Vec::new();
        conn.consume(
            "bulk",
            "c1",
            0,
            Box::new(move |d| {
                seen.push(d.body.decode().unwrap().as_i64().unwrap());
                conn2.ack(d.delivery_tag).unwrap();
                if seen.len() == 40 {
                    done_tx.send(seen.clone()).unwrap();
                }
            }),
        )
        .unwrap();
        let seen = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(seen, (0..40).collect::<Vec<i64>>(), "batch dispatch must preserve order");
        let deadline = Instant::now() + Duration::from_secs(2);
        while broker.broker().queue_unacked("bulk") != Some(0) {
            assert!(Instant::now() < deadline, "pipelined acks must drain the queue");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(broker.broker().delivery_index_len(), 0);
    }

    #[test]
    fn close_is_clean_and_idempotent() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        assert!(!conn.is_closed());
        conn.close();
        // A second connection still works (broker unaffected).
        let conn2 = open(&broker);
        assert!(conn2.request(&ClientRequest::Status).is_ok());
        conn2.close();
    }

    #[test]
    fn duplicate_consume_tag_refused_without_killing_original() {
        // Regression: `consume` used to insert the new handler before the
        // broker answered, clobbering a live consumer's handler — and its
        // error path then removed the original's registration entirely.
        let broker = InprocBroker::new();
        let conn = open(&broker);
        declare(&conn, "q");
        let (tx, rx) = channel();
        conn.consume(
            "q",
            "c1",
            0,
            Box::new(move |d| {
                tx.send(d.body.decode().unwrap()).unwrap();
            }),
        )
        .unwrap();
        // Same tag again: refused up front…
        let err = conn.consume("q", "c1", 0, Box::new(|_| {})).unwrap_err();
        assert!(matches!(err, Error::DuplicateSubscriber(_)), "{err:?}");
        // …and the original consumer still works.
        publish(&conn, "q", Value::str("still-alive"));
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(2)).unwrap(),
            Value::str("still-alive")
        );
        conn.close();
    }

    #[test]
    fn failed_consume_rolls_back_its_own_registration() {
        let broker = InprocBroker::new();
        let conn = open(&broker);
        // Consuming a queue that does not exist fails broker-side…
        assert!(conn.consume("ghost", "c1", 0, Box::new(|_| {})).is_err());
        // …and the rollback frees the tag for a later, valid consume.
        declare(&conn, "q");
        let (tx, rx) = channel();
        conn.consume(
            "q",
            "c1",
            0,
            Box::new(move |d| {
                tx.send(d.body.decode().unwrap()).unwrap();
            }),
        )
        .unwrap();
        publish(&conn, "q", Value::I64(9));
        assert_eq!(rx.recv_timeout(Duration::from_secs(2)).unwrap(), Value::I64(9));
        conn.close();
    }

    #[test]
    fn cross_thread_ack_escapes_open_batch_window() {
        // Regression: acks from *any* thread used to coalesce into the
        // comm thread's open batch window, so a user thread acking an old
        // delivery mid-batch had its ack parked behind unrelated handlers.
        let broker = InprocBroker::new();
        let conn = Arc::new(open(&broker));
        declare(&conn, "q");
        for i in 0..8 {
            publish(&conn, "q", Value::I64(i));
        }
        let (tag_tx, tag_rx) = channel();
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let gate2 = Arc::clone(&gate);
        let conn2 = Arc::clone(&conn);
        let mut first = true;
        conn.consume(
            "q",
            "c1",
            0,
            Box::new(move |d| {
                if first {
                    first = false;
                    // Hand the tag to the main thread and stall the batch.
                    tag_tx.send(d.delivery_tag).unwrap();
                    while !gate2.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                } else {
                    conn2.ack(d.delivery_tag).unwrap();
                }
            }),
        )
        .unwrap();
        let tag = tag_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // The comm thread is stalled inside the batch (window open). An
        // ack from this thread must go out NOW, not when the batch ends.
        conn.ack(tag).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let unacked = broker.broker().queue_unacked("q").unwrap();
            if unacked == 7 {
                break; // our ack landed while the batch is still stalled
            }
            assert!(
                Instant::now() < deadline,
                "cross-thread ack was parked in the batch window (unacked={unacked})"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        gate.store(true, Ordering::Relaxed);
        let deadline = Instant::now() + Duration::from_secs(5);
        while broker.broker().queue_unacked("q") != Some(0) {
            assert!(Instant::now() < deadline, "remaining handler acks must drain");
            std::thread::sleep(Duration::from_millis(5));
        }
        conn.close();
    }

    /// Links a spying factory has produced, so tests can sever them.
    type LinkLog = Arc<Mutex<Vec<Arc<dyn Link>>>>;

    /// A factory over an [`InprocBroker`] that keeps handles to every link
    /// it has produced, so tests can sever the live one.
    fn spying_factory(broker: Arc<InprocBroker>, produced: LinkLog) -> LinkFactory {
        Box::new(move || {
            let link = broker.connect();
            produced.lock().unwrap().push(Arc::clone(&link));
            Ok(link)
        })
    }

    fn reconnecting_config() -> ConnectionConfig {
        ConnectionConfig {
            reconnect_max_retries: 20,
            reconnect_backoff_ms: 5,
            request_timeout: Duration::from_secs(10),
            ..Default::default()
        }
    }

    #[test]
    fn link_death_revives_consumers_transparently() {
        let broker = Arc::new(InprocBroker::new());
        let produced: LinkLog = Arc::new(Mutex::new(Vec::new()));
        let conn = Connection::open_with_factory(
            spying_factory(Arc::clone(&broker), Arc::clone(&produced)),
            reconnecting_config(),
        )
        .unwrap();
        declare(&conn, "q");
        let (tx, rx) = channel();
        conn.consume(
            "q",
            "c1",
            0,
            Box::new(move |d| {
                tx.send(d.body.decode().unwrap()).unwrap();
            }),
        )
        .unwrap();
        publish(&conn, "q", Value::I64(1));
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), Value::I64(1));

        // Sever the live link out from under the connection.
        produced.lock().unwrap()[0].close();

        // The next publish either parks across the outage or goes through
        // post-revival; the revived consumer must still receive it.
        publish(&conn, "q", Value::I64(2));
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), Value::I64(2));
        assert!(!conn.is_closed(), "outage must not poison the connection");
        assert!(conn.metrics().counter("client.reconnects_total").get() >= 1);
        assert!(conn.metrics().counter("client.replayed_consumers_total").get() >= 1);
        conn.close();
    }

    #[test]
    fn topology_replay_reteaches_a_fresh_broker() {
        // Second dial lands on a brand-new broker (process restart that
        // lost all state): the journal must re-declare queue + consumer.
        let broker_a = Arc::new(InprocBroker::new());
        let broker_b = Arc::new(InprocBroker::new());
        let dials = Arc::new(AtomicU64::new(0));
        let links: LinkLog = Arc::new(Mutex::new(Vec::new()));
        let factory: LinkFactory = {
            let (a, b) = (Arc::clone(&broker_a), Arc::clone(&broker_b));
            let (dials, links) = (Arc::clone(&dials), Arc::clone(&links));
            Box::new(move || {
                let n = dials.fetch_add(1, Ordering::Relaxed);
                let link = if n == 0 { a.connect() } else { b.connect() };
                links.lock().unwrap().push(Arc::clone(&link));
                Ok(link)
            })
        };
        let conn = Connection::open_with_factory(factory, reconnecting_config()).unwrap();
        declare(&conn, "q");
        let (tx, rx) = channel();
        conn.consume(
            "q",
            "c1",
            0,
            Box::new(move |d| {
                tx.send(d.body.decode().unwrap()).unwrap();
            }),
        )
        .unwrap();
        links.lock().unwrap()[0].close();
        publish(&conn, "q", Value::str("reborn"));
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), Value::str("reborn"));
        // The new broker was re-taught the queue; the old one is history.
        assert!(broker_b.broker().queue_depth("q").is_some());
        conn.close();
    }

    #[test]
    fn stale_pre_outage_ack_is_dropped_not_misapplied() {
        // A tag delivered before an outage names nothing after it (the
        // broker requeued the message; a restarted broker may even reuse
        // the value for a different message). Acking it post-revival must
        // be a no-op — the redelivery's new tag is the live one.
        let broker = Arc::new(InprocBroker::new());
        let produced: LinkLog = Arc::new(Mutex::new(Vec::new()));
        let conn = Connection::open_with_factory(
            spying_factory(Arc::clone(&broker), Arc::clone(&produced)),
            reconnecting_config(),
        )
        .unwrap();
        declare(&conn, "q");
        publish(&conn, "q", Value::str("once"));
        let (tag_tx, tag_rx) = channel();
        conn.consume(
            "q",
            "c1",
            1,
            Box::new(move |d| {
                tag_tx.send(d.delivery_tag).unwrap(); // never acks itself
            }),
        )
        .unwrap();
        let stale_tag = tag_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        produced.lock().unwrap()[0].close();
        // The broker requeues the unacked message on disconnect; the
        // revived consumer gets it again under a fresh tag.
        let live_tag = tag_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_ne!(stale_tag, live_tag);
        conn.ack(stale_tag).unwrap(); // dropped as stale
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(
            broker.broker().queue_unacked("q"),
            Some(1),
            "stale ack must not retire the redelivered message"
        );
        conn.ack(live_tag).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        while broker.broker().queue_unacked("q") != Some(0) {
            assert!(Instant::now() < deadline, "live ack must drain");
            std::thread::sleep(Duration::from_millis(5));
        }
        conn.close();
    }

    #[test]
    fn retries_exhausted_closes_terminally() {
        let broker = Arc::new(InprocBroker::new());
        let dials = Arc::new(AtomicU64::new(0));
        let links: LinkLog = Arc::new(Mutex::new(Vec::new()));
        let factory: LinkFactory = {
            let broker = Arc::clone(&broker);
            let (dials, links) = (Arc::clone(&dials), Arc::clone(&links));
            Box::new(move || {
                if dials.fetch_add(1, Ordering::Relaxed) == 0 {
                    let link = broker.connect();
                    links.lock().unwrap().push(Arc::clone(&link));
                    Ok(link)
                } else {
                    Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::ConnectionRefused,
                        "broker gone",
                    )))
                }
            })
        };
        let conn = Connection::open_with_factory(
            factory,
            ConnectionConfig {
                reconnect_max_retries: 3,
                reconnect_backoff_ms: 1,
                ..Default::default()
            },
        )
        .unwrap();
        declare(&conn, "q");
        // Sever the only link; every re-dial is then refused.
        links.lock().unwrap()[0].close();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !conn.is_closed() {
            assert!(Instant::now() < deadline, "exhausted retries must close the connection");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(conn.request(&ClientRequest::Status).is_err());
        conn.close();
    }
}
