//! The framed, bidirectional link both the broker session and the client
//! connection are written against. Two implementations:
//!
//! * [`TcpLink`] — frames over a `TcpStream` (cross-process / cross-host).
//! * [`InprocLink`] — a crossed pair of channels (embedded broker; this is
//!   the "individual laptop" deployment and the test/bench substrate).
//!
//! `send` is callable from any thread; `recv` is owned by one reader.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::wire::{read_frame, write_frame, Frame};

/// A framed bidirectional message link.
pub trait Link: Send + Sync {
    /// Send one frame (thread-safe).
    fn send(&self, frame: &Frame) -> Result<()>;
    /// Send several frames as one write unit. The default loops `send`;
    /// implementations with a buffered writer (TCP) override this to take
    /// the write lock once and flush once — one syscall per batch instead
    /// of one per frame.
    fn send_batch(&self, frames: &[Frame]) -> Result<()> {
        for frame in frames {
            self.send(frame)?;
        }
        Ok(())
    }
    /// Receive the next frame, waiting up to `timeout`.
    /// `Err(Timeout)` = nothing arrived; `Err(Closed)`/`Err(Io)` = link dead.
    fn recv_timeout(&self, timeout: Duration) -> Result<Frame>;
    /// Close the link (idempotent). Wakes any blocked `recv_timeout`.
    fn close(&self);
    /// Human-readable peer description for logs.
    fn peer(&self) -> String;
}

// ---------------------------------------------------------------- TCP --

/// TCP implementation. The socket is split: reads go through a cloned
/// handle guarded by `reader`, writes through a buffered handle in
/// `writer`; each side has its own lock so a blocked reader never starves
/// senders.
pub struct TcpLink {
    reader: Mutex<BufReader<TcpStream>>,
    writer: Mutex<BufWriter<TcpStream>>,
    stream: TcpStream,
    peer: String,
}

impl TcpLink {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        let peer =
            stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "<unknown>".into());
        let read_half = stream.try_clone()?;
        let write_half = stream.try_clone()?;
        Ok(TcpLink {
            reader: Mutex::new(BufReader::new(read_half)),
            writer: Mutex::new(BufWriter::new(write_half)),
            stream,
            peer,
        })
    }
}

impl Link for TcpLink {
    fn send(&self, frame: &Frame) -> Result<()> {
        let mut w = self.writer.lock().unwrap();
        write_frame(&mut *w, frame)?;
        w.flush()?;
        Ok(())
    }

    fn send_batch(&self, frames: &[Frame]) -> Result<()> {
        let mut w = self.writer.lock().unwrap();
        for frame in frames {
            write_frame(&mut *w, frame)?;
        }
        w.flush()?;
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Frame> {
        let mut r = self.reader.lock().unwrap();
        // A zero timeout would mean "block forever" to the OS; clamp up.
        r.get_ref().set_read_timeout(Some(timeout.max(Duration::from_millis(1))))?;
        match read_frame(&mut *r) {
            Ok(f) => Ok(f),
            Err(Error::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Err(Error::Timeout("recv".into()))
            }
            Err(Error::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                Err(Error::Closed("peer closed".into()))
            }
            Err(e) => Err(e),
        }
    }

    fn close(&self) {
        self.stream.shutdown(std::net::Shutdown::Both).ok();
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

/// Connect to a broker over TCP.
pub fn connect_tcp(addr: impl ToSocketAddrs) -> Result<TcpLink> {
    let stream = TcpStream::connect(addr)?;
    TcpLink::new(stream)
}

/// Connect with a per-dial timeout. The reconnect path uses this: a
/// blackholed broker host must not pin the dialing (communication) thread
/// for the OS connect timeout — that would make `close()` during an
/// outage block for minutes instead of the dial budget.
pub fn connect_tcp_bounded(addr: &str, timeout: Duration) -> Result<TcpLink> {
    let mut last: Option<std::io::Error> = None;
    for sock_addr in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sock_addr, timeout) {
            Ok(stream) => return TcpLink::new(stream),
            Err(e) => last = Some(e),
        }
    }
    Err(match last {
        Some(e) => Error::Io(e),
        None => Error::Config(format!("cannot resolve '{addr}'")),
    })
}

// ------------------------------------------------------------- inproc --

/// In-process link: a crossed channel pair.
pub struct InprocLink {
    tx: Sender<Frame>,
    rx: Mutex<Receiver<Frame>>,
    name: String,
}

/// Create a connected pair of in-process links (client half, server half).
pub fn inproc_pair() -> (InprocLink, InprocLink) {
    let (a_tx, a_rx) = channel();
    let (b_tx, b_rx) = channel();
    (
        InprocLink { tx: a_tx, rx: Mutex::new(b_rx), name: "inproc-client".into() },
        InprocLink { tx: b_tx, rx: Mutex::new(a_rx), name: "inproc-server".into() },
    )
}

impl Link for InprocLink {
    fn send(&self, frame: &Frame) -> Result<()> {
        self.tx.send(frame.clone()).map_err(|_| Error::Closed("inproc peer gone".into()))
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Frame> {
        let rx = self.rx.lock().unwrap();
        match rx.recv_timeout(timeout) {
            Ok(f) => Ok(f),
            Err(RecvTimeoutError::Timeout) => Err(Error::Timeout("recv".into())),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Closed("inproc peer gone".into())),
        }
    }

    fn close(&self) {
        // Dropping our sender is what closes the peer; nothing to do here —
        // the object model keeps the sender alive until drop. We signal by
        // sending a Goodbye instead.
        self.tx.send(Frame::goodbye("close")).ok();
    }

    fn peer(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::Value;
    use std::net::TcpListener;

    #[test]
    fn inproc_pair_roundtrip() {
        let (client, server) = inproc_pair();
        client.send(&Frame::data(&Value::str("ping"))).unwrap();
        let got = server.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got.value().unwrap(), Value::str("ping"));
        server.send(&Frame::data(&Value::str("pong"))).unwrap();
        assert_eq!(
            client.recv_timeout(Duration::from_secs(1)).unwrap().value().unwrap(),
            Value::str("pong")
        );
    }

    #[test]
    fn inproc_timeout() {
        let (client, _server) = inproc_pair();
        match client.recv_timeout(Duration::from_millis(10)) {
            Err(Error::Timeout(_)) => {}
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn inproc_detects_dropped_peer() {
        let (client, server) = inproc_pair();
        drop(server);
        assert!(matches!(client.recv_timeout(Duration::from_millis(10)), Err(Error::Closed(_))));
        assert!(matches!(client.send(&Frame::heartbeat()), Err(Error::Closed(_))));
    }

    #[test]
    fn send_batch_preserves_frame_order() {
        let (client, server) = inproc_pair();
        let frames: Vec<Frame> = (0..5).map(|i| Frame::data(&Value::I64(i))).collect();
        client.send_batch(&frames).unwrap();
        for i in 0..5 {
            let got = server.recv_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!(got.value().unwrap(), Value::I64(i));
        }
    }

    #[test]
    fn tcp_send_batch_is_one_write_unit() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server_thread = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let link = TcpLink::new(stream).unwrap();
            (0..10)
                .map(|_| {
                    link.recv_timeout(Duration::from_secs(2)).unwrap().value().unwrap()
                })
                .collect::<Vec<_>>()
        });
        let client = connect_tcp(addr).unwrap();
        let frames: Vec<Frame> = (0..10).map(|i| Frame::data(&Value::I64(i))).collect();
        client.send_batch(&frames).unwrap();
        let got = server_thread.join().unwrap();
        assert_eq!(got, (0..10).map(Value::I64).collect::<Vec<_>>());
    }

    #[test]
    fn tcp_link_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server_thread = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let link = TcpLink::new(stream).unwrap();
            let f = link.recv_timeout(Duration::from_secs(2)).unwrap();
            link.send(&f).unwrap(); // echo
        });
        let client = connect_tcp(addr).unwrap();
        let v = Value::map([("x", Value::F32s(vec![1.0, 2.0, 3.0]))]);
        client.send(&Frame::data(&v)).unwrap();
        let echoed = client.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(echoed.value().unwrap(), v);
        server_thread.join().unwrap();
    }

    #[test]
    fn tcp_recv_times_out() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _srv = std::thread::spawn(move || {
            let (_stream, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_millis(200));
        });
        let client = connect_tcp(addr).unwrap();
        assert!(matches!(
            client.recv_timeout(Duration::from_millis(20)),
            Err(Error::Timeout(_))
        ));
    }

    #[test]
    fn tcp_detects_closed_peer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            drop(stream);
        });
        let client = connect_tcp(addr).unwrap();
        srv.join().unwrap();
        match client.recv_timeout(Duration::from_millis(500)) {
            Err(Error::Closed(_)) | Err(Error::Io(_)) => {}
            other => panic!("expected closed, got {other:?}"),
        }
    }
}
