//! A fixed-size worker pool (no rayon offline; ~60 lines is all we need).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::metrics::{Counter, Registry};

type Job = Box<dyn FnOnce() + Send>;

/// Fixed pool of worker threads fed by a shared queue.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<Counter>,
}

impl WorkerPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize, name: &str) -> Self {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(Counter::new());
        let workers = (0..size.max(1))
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                let thread_name = format!("{name}-{i}");
                std::thread::Builder::new()
                    .name(thread_name.clone())
                    .spawn(move || loop {
                        // Hold the lock only while receiving.
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                // Job panics are isolated (the scheduler
                                // already catches step panics; this guards
                                // everything else) — but never silent: each
                                // one is logged with its payload and counted,
                                // so a daemon quietly eating work shows up in
                                // metrics.
                                if let Err(payload) = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                ) {
                                    let msg = payload
                                        .downcast_ref::<&str>()
                                        .map(|s| (*s).to_string())
                                        .or_else(|| {
                                            payload.downcast_ref::<String>().cloned()
                                        })
                                        .unwrap_or_else(|| {
                                            "<non-string panic payload>".to_string()
                                        });
                                    log::error!(
                                        "worker '{thread_name}': job panicked: {msg}"
                                    );
                                    panics.inc();
                                }
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), workers, panics }
    }

    /// Number of jobs that panicked since the pool started.
    pub fn job_panics(&self) -> u64 {
        self.panics.get()
    }

    /// Install the pool's panic counter into `registry` as
    /// `daemon.job_panics_total` so snapshots include it.
    pub fn register_metrics(&self, registry: &Registry) {
        registry.register_counter("daemon.job_panics_total", Arc::clone(&self.panics));
    }

    /// Submit a job. Errors only after shutdown.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<(), ()> {
        match &self.tx {
            Some(tx) => tx.send(Box::new(job)).map_err(|_| ()),
            None => Err(()),
        }
    }

    /// A detached submit handle (owning clone of the job channel). Note:
    /// an outstanding sender keeps pool threads alive past `drop`, but
    /// `shutdown`/`Drop` still join after all senders are gone.
    pub fn sender(&self) -> Sender<Job> {
        self.tx.as_ref().expect("pool already shut down").clone()
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Graceful shutdown: finish queued jobs, then join.
    pub fn shutdown(mut self) {
        self.tx.take(); // closing the channel ends the workers
        for h in self.workers.drain(..) {
            h.join().ok();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Abrupt semantics: close the job channel and DETACH. Workers
        // finish their current job in the background and exit; nothing
        // waits on them. This models a killed daemon — in-flight broker
        // messages stay unacked and get requeued. Use `shutdown()` for the
        // graceful join.
        self.tx.take();
        self.workers.drain(..);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn all_jobs_run() {
        let pool = WorkerPool::new(4, "t");
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&count);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = WorkerPool::new(4, "t");
        let (tx, rx) = channel();
        for _ in 0..4 {
            let tx = tx.clone();
            pool.submit(move || {
                tx.send(()).unwrap();
                std::thread::sleep(Duration::from_millis(100));
            })
            .unwrap();
        }
        // All four must start within much less than 4 × 100 ms.
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            rx.recv_timeout(Duration::from_secs(1)).unwrap();
        }
        assert!(t0.elapsed() < Duration::from_millis(200));
        pool.shutdown();
    }

    #[test]
    fn panicking_job_does_not_kill_pool() {
        let pool = WorkerPool::new(1, "t");
        pool.submit(|| panic!("boom")).unwrap();
        let (tx, rx) = channel();
        pool.submit(move || tx.send(42).unwrap()).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 42);
        pool.shutdown();
    }

    #[test]
    fn panicking_job_is_counted_and_worker_survives() {
        let pool = WorkerPool::new(1, "t");
        let registry = Registry::new();
        pool.register_metrics(&registry);
        assert_eq!(pool.job_panics(), 0);
        pool.submit(|| panic!("boom")).unwrap();
        // Non-&str payloads are recorded too.
        pool.submit(|| std::panic::panic_any(String::from("heap boom"))).unwrap();
        // The same single worker must still be alive to run this.
        let (tx, rx) = channel();
        pool.submit(move || tx.send(7).unwrap()).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 7);
        assert_eq!(pool.job_panics(), 2);
        assert_eq!(registry.counter("daemon.job_panics_total").get(), 2);
        pool.shutdown();
    }

    #[test]
    fn min_one_worker() {
        let pool = WorkerPool::new(0, "t");
        assert_eq!(pool.size(), 1);
        pool.shutdown();
    }
}
