//! The daemon: AiiDA's worker processes. Consumes the task queue through a
//! communicator, multiplexes processes onto a fixed-size event-driven
//! scheduler (waiting processes hold no thread), and survives both
//! graceful and abrupt shutdown — in the abrupt case the broker requeues
//! its unacked tasks to the surviving workers (§I.A).
//!
//! A daemon whose communicator was connected through a link factory
//! (`RmqCommunicator::connect_tcp`, which `kiwi worker` uses) also
//! survives *broker* outages: the connection re-dials with backoff and
//! replays its topology journal, so the task subscription resumes after a
//! broker restart with no daemon-side code.

pub mod pool;
pub mod worker;

pub use pool::WorkerPool;
pub use worker::{Daemon, DaemonConfig};
