//! The daemon: AiiDA's worker processes. Consumes the task queue through a
//! communicator, runs each process on a worker-pool thread, and survives
//! both graceful and abrupt shutdown — in the abrupt case the broker
//! requeues its unacked tasks to the surviving workers (§I.A).

pub mod pool;
pub mod worker;

pub use pool::WorkerPool;
pub use worker::{Daemon, DaemonConfig};
