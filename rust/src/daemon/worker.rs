//! The daemon proper: one communicator, one event-driven [`Scheduler`],
//! one task-queue subscription. The prefetch window is sized to the
//! scheduler's *residency* cap rather than its thread count — waiting
//! processes no longer occupy a thread, so a 4-worker daemon can hold
//! hundreds of in-flight processes while the broker keeps distributing
//! the excess to other daemons.

use std::sync::Arc;

use crate::communicator::{Communicator, TaskHandler};
use crate::error::Result;
use crate::wire::Value;
use crate::workflow::checkpoint::CheckpointStore;
use crate::workflow::launcher::DEFAULT_TASK_QUEUE;
use crate::workflow::registry::ProcessRegistry;
use crate::workflow::scheduler::{Scheduler, SchedulerConfig};

/// Daemon tuning.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Scheduler worker threads (concurrent *steps*, not processes).
    pub workers: usize,
    /// Resident-process ceiling before long-waiting processes are
    /// checkpointed and evicted from memory. 0 = never park. Also sizes
    /// the broker prefetch window (0 = unlimited prefetch).
    pub max_resident_processes: usize,
    /// Task queue to consume.
    pub task_queue: String,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            workers: 4,
            max_resident_processes: 1024,
            task_queue: DEFAULT_TASK_QUEUE.into(),
        }
    }
}

/// A running daemon. Dropping it is an *abrupt* shutdown (unacked tasks
/// requeue); [`Daemon::shutdown`] is the graceful path (workers finish
/// their current step, then join).
pub struct Daemon {
    comm: Arc<dyn Communicator>,
    subscription: String,
    sched: Arc<Scheduler>,
}

impl Daemon {
    /// Start consuming tasks.
    pub fn start(
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: ProcessRegistry,
        config: DaemonConfig,
    ) -> Result<Self> {
        let sched = Arc::new(Scheduler::start(
            Arc::clone(&comm),
            store,
            registry,
            SchedulerConfig {
                workers: config.workers,
                max_resident: config.max_resident_processes,
                task_queue: config.task_queue.clone(),
            },
        )?);
        let handler: TaskHandler = {
            let sched = Arc::clone(&sched);
            // Admission only parses and enqueues — cheap enough to run
            // directly on the communicator's delivery thread.
            Box::new(move |task: Value, ctx| sched.admit_task(task, ctx))
        };
        let prefetch = u32::try_from(config.max_resident_processes).unwrap_or(u32::MAX);
        let subscription = comm.task_queue(&config.task_queue, prefetch, handler)?;
        Ok(Daemon { comm, subscription, sched })
    }

    /// The scheduler driving this daemon's processes (stats, waits,
    /// checkpoint resumption).
    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.sched
    }

    /// Re-enqueue every non-terminal checkpoint in the store through the
    /// task queue. Call after a restart to pick interrupted work back up.
    pub fn resume_stored(&self) -> Result<usize> {
        self.sched.resume_stored()
    }

    /// Graceful shutdown (paper §I.A: "gracefully or abruptly shut down and
    /// no task will be lost"): stop consuming, finish in-flight steps.
    pub fn shutdown(self) {
        self.comm.remove_task_subscriber(&self.subscription).ok();
        self.sched.shutdown();
        // Drop then runs abort(), which is a no-op after shutdown.
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        // Abrupt semantics: the task handler owns a scheduler Arc via the
        // communicator's subscriber map, so dropping the Daemon alone
        // would leave worker threads polling forever. Signal shutdown
        // without joining — in-flight deliveries stay unacked and the
        // broker requeues them (the in-process `kill -9`).
        self.comm.remove_task_subscriber(&self.subscription).ok();
        self.sched.abort();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::InprocBroker;
    use crate::communicator::{RmqCommunicator, RmqConfig};
    use crate::wire::Value;
    use crate::workflow::checkpoint::MemoryCheckpointStore;
    use crate::workflow::process::{ProcessLogic, StepContext, StepOutcome};
    use crate::workflow::RemoteLauncher;
    use std::time::Duration;

    struct Doubler {
        x: i64,
    }
    impl ProcessLogic for Doubler {
        fn step(&mut self, _: u32, _: &mut StepContext) -> crate::error::Result<StepOutcome> {
            Ok(StepOutcome::Finish(Value::map([("doubled", Value::I64(self.x * 2))])))
        }
        fn save_state(&self) -> Value {
            Value::map([("x", Value::I64(self.x))])
        }
        fn load_state(&mut self, state: &Value) -> crate::error::Result<()> {
            self.x = match state.get_opt("inputs") {
                Some(inputs) => inputs.get_i64("x")?,
                None => state.get_i64("x")?,
            };
            Ok(())
        }
    }

    fn registry() -> ProcessRegistry {
        let r = ProcessRegistry::new();
        r.register("doubler", || Box::new(Doubler { x: 0 }));
        r
    }

    #[test]
    fn daemon_executes_launched_processes() {
        let broker = InprocBroker::new();
        let worker_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let client_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let daemon = Daemon::start(
            Arc::clone(&worker_comm),
            store,
            registry(),
            DaemonConfig { workers: 2, ..Default::default() },
        )
        .unwrap();

        let launcher = RemoteLauncher::new(Arc::clone(&client_comm));
        let futs: Vec<_> = (0..6)
            .map(|i| {
                launcher
                    .launch("doubler", Value::map([("x", Value::I64(i))]))
                    .unwrap()
                    .1
            })
            .collect();
        for (i, f) in futs.into_iter().enumerate() {
            let record = f.wait(Duration::from_secs(10)).unwrap();
            assert_eq!(record.get_str("state").unwrap(), "finished");
            assert_eq!(
                record.get("outputs").unwrap().get_i64("doubled").unwrap(),
                (i as i64) * 2
            );
        }
        daemon.shutdown();
    }

    #[test]
    fn daemon_handles_more_processes_than_workers() {
        // Residency, not thread count, bounds concurrency: 2 workers must
        // carry 32 simultaneously-waiting processes to completion.
        struct Nap;
        impl ProcessLogic for Nap {
            fn step(
                &mut self,
                step: u32,
                _: &mut StepContext,
            ) -> crate::error::Result<StepOutcome> {
                if step == 0 {
                    Ok(StepOutcome::Wait(crate::workflow::process::WaitCondition::Timer(
                        Duration::from_millis(30),
                    )))
                } else {
                    Ok(StepOutcome::Finish(Value::str("ok")))
                }
            }
            fn save_state(&self) -> Value {
                Value::Null
            }
            fn load_state(&mut self, _: &Value) -> crate::error::Result<()> {
                Ok(())
            }
        }
        let broker = InprocBroker::new();
        let worker_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let client_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let reg = ProcessRegistry::new();
        reg.register("nap", || Box::new(Nap));
        let daemon = Daemon::start(
            Arc::clone(&worker_comm),
            store,
            reg,
            DaemonConfig { workers: 2, ..Default::default() },
        )
        .unwrap();

        let launcher = RemoteLauncher::new(Arc::clone(&client_comm));
        let futs: Vec<_> =
            (0..32).map(|_| launcher.launch("nap", Value::Null).unwrap().1).collect();
        for f in futs {
            let record = f.wait(Duration::from_secs(10)).unwrap();
            assert_eq!(record.get_str("state").unwrap(), "finished");
        }
        daemon.shutdown();
    }

    #[test]
    fn abrupt_daemon_death_requeues_to_survivor() {
        // The paper's core §I.A claim at the full-stack level: kill a
        // daemon mid-task, watch the task finish elsewhere.
        let broker = InprocBroker::new();
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());

        // A process type that stalls (short timer waits) until a release
        // flag flips — lets us control when workers can finish.
        struct Stall {
            release: Arc<std::sync::atomic::AtomicBool>,
        }
        impl ProcessLogic for Stall {
            fn step(&mut self, _: u32, _: &mut StepContext) -> crate::error::Result<StepOutcome> {
                if self.release.load(std::sync::atomic::Ordering::Relaxed) {
                    Ok(StepOutcome::Finish(Value::str("done")))
                } else {
                    Ok(StepOutcome::Wait(crate::workflow::process::WaitCondition::Timer(
                        Duration::from_millis(20),
                    )))
                }
            }
            fn save_state(&self) -> Value {
                Value::Null
            }
            fn load_state(&mut self, _: &Value) -> crate::error::Result<()> {
                Ok(())
            }
        }

        let release = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let reg = ProcessRegistry::new();
        {
            let release = Arc::clone(&release);
            reg.register("stall", move || Box::new(Stall { release: Arc::clone(&release) }));
        }

        let doomed_typed =
            Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
        let doomed_comm: Arc<dyn Communicator> = Arc::clone(&doomed_typed) as _;
        let doomed = Daemon::start(
            Arc::clone(&doomed_comm),
            Arc::clone(&store),
            reg.clone(),
            DaemonConfig { workers: 1, ..Default::default() },
        )
        .unwrap();

        let client_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let launcher = RemoteLauncher::new(Arc::clone(&client_comm));
        let (_pid, fut) = launcher.launch("stall", Value::Null).unwrap();

        // Give the doomed daemon time to pick the task up, then kill it
        // abruptly: sever its broker connection with the task unacked
        // (the in-process equivalent of `kill -9`).
        std::thread::sleep(Duration::from_millis(200));
        doomed_typed.close();
        drop(doomed); // abort(): detached workers wind down, task stays unacked

        // Second daemon; release the stall so it can finish.
        release.store(true, std::sync::atomic::Ordering::Relaxed);
        let survivor_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let survivor = Daemon::start(
            Arc::clone(&survivor_comm),
            Arc::clone(&store),
            reg,
            DaemonConfig { workers: 1, ..Default::default() },
        )
        .unwrap();

        let record = fut.wait(Duration::from_secs(10)).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        survivor.shutdown();
    }
}
