//! The daemon proper: one communicator, one worker pool, one task-queue
//! subscription with `prefetch = pool size` — the broker never hands a
//! worker more processes than it has threads, so work distributes evenly
//! across daemons (AiiDA runs the same prefetch policy).

use std::sync::Arc;

use crate::communicator::{Communicator, TaskHandler};
use crate::daemon::pool::WorkerPool;
use crate::error::Result;
use crate::wire::Value;
use crate::workflow::checkpoint::CheckpointStore;
use crate::workflow::launcher::{ProcessLauncher, DEFAULT_TASK_QUEUE};
use crate::workflow::registry::ProcessRegistry;

/// Daemon tuning.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Worker threads = max concurrent processes on this daemon.
    pub workers: usize,
    /// Task queue to consume.
    pub task_queue: String,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig { workers: 4, task_queue: DEFAULT_TASK_QUEUE.into() }
    }
}

/// A running daemon. Dropping it is an *abrupt* shutdown (unacked tasks
/// requeue); [`Daemon::shutdown`] is the graceful path (drains the pool).
pub struct Daemon {
    comm: Arc<dyn Communicator>,
    subscription: String,
    pool: Option<WorkerPool>,
}

impl Daemon {
    /// Start consuming tasks.
    pub fn start(
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: ProcessRegistry,
        config: DaemonConfig,
    ) -> Result<Self> {
        let pool = WorkerPool::new(config.workers, "kiwi-daemon");
        let launcher = Arc::new(ProcessLauncher::with_queue(
            Arc::clone(&comm),
            store,
            registry,
            &config.task_queue,
        ));
        let handler: TaskHandler = {
            let launcher = Arc::clone(&launcher);
            // The communicator invokes this on its communication thread;
            // we immediately punt to the pool so the thread stays free for
            // heartbeats, acks and further deliveries.
            let pool_tx = pool_sender(&pool);
            Box::new(move |task: Value, ctx| {
                let launcher = Arc::clone(&launcher);
                if pool_tx(Box::new(move || launcher.handle_task(task, ctx))).is_err() {
                    log::warn!("daemon: pool gone; task will be requeued by broker");
                }
            })
        };
        let subscription =
            comm.task_queue(&config.task_queue, config.workers as u32, handler)?;
        Ok(Daemon { comm, subscription, pool: Some(pool) })
    }

    /// Graceful shutdown (paper §I.A: "gracefully or abruptly shut down and
    /// no task will be lost"): stop consuming, finish in-flight processes.
    pub fn shutdown(mut self) {
        self.comm.remove_task_subscriber(&self.subscription).ok();
        if let Some(pool) = self.pool.take() {
            pool.shutdown();
        }
    }
}

type PoolSender = Box<dyn Fn(Box<dyn FnOnce() + Send>) -> std::result::Result<(), ()> + Send>;

fn pool_sender(pool: &WorkerPool) -> PoolSender {
    // WorkerPool::submit borrows the pool; we need a handle the closure can
    // own. Clone the underlying channel sender.
    let tx = pool.sender();
    Box::new(move |job| tx.send(job).map_err(|_| ()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::InprocBroker;
    use crate::communicator::{RmqCommunicator, RmqConfig};
    use crate::wire::Value;
    use crate::workflow::checkpoint::MemoryCheckpointStore;
    use crate::workflow::process::{ProcessLogic, StepContext, StepOutcome};
    use crate::workflow::RemoteLauncher;
    use std::time::Duration;

    struct Doubler {
        x: i64,
    }
    impl ProcessLogic for Doubler {
        fn step(&mut self, _: u32, _: &mut StepContext) -> crate::error::Result<StepOutcome> {
            Ok(StepOutcome::Finish(Value::map([("doubled", Value::I64(self.x * 2))])))
        }
        fn save_state(&self) -> Value {
            Value::map([("x", Value::I64(self.x))])
        }
        fn load_state(&mut self, state: &Value) -> crate::error::Result<()> {
            self.x = match state.get_opt("inputs") {
                Some(inputs) => inputs.get_i64("x")?,
                None => state.get_i64("x")?,
            };
            Ok(())
        }
    }

    fn registry() -> ProcessRegistry {
        let r = ProcessRegistry::new();
        r.register("doubler", || Box::new(Doubler { x: 0 }));
        r
    }

    #[test]
    fn daemon_executes_launched_processes() {
        let broker = InprocBroker::new();
        let worker_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let client_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let daemon = Daemon::start(
            Arc::clone(&worker_comm),
            store,
            registry(),
            DaemonConfig { workers: 2, ..Default::default() },
        )
        .unwrap();

        let launcher = RemoteLauncher::new(Arc::clone(&client_comm));
        let futs: Vec<_> = (0..6)
            .map(|i| {
                launcher
                    .launch("doubler", Value::map([("x", Value::I64(i))]))
                    .unwrap()
                    .1
            })
            .collect();
        for (i, f) in futs.into_iter().enumerate() {
            let record = f.wait(Duration::from_secs(10)).unwrap();
            assert_eq!(record.get_str("state").unwrap(), "finished");
            assert_eq!(
                record.get("outputs").unwrap().get_i64("doubled").unwrap(),
                (i as i64) * 2
            );
        }
        daemon.shutdown();
    }

    #[test]
    fn abrupt_daemon_death_requeues_to_survivor() {
        // The paper's core §I.A claim at the full-stack level: kill a
        // daemon mid-task, watch the task finish elsewhere.
        let broker = InprocBroker::new();
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());

        // A process type that stalls until a file "release" flag appears —
        // lets us control when workers can finish.
        struct Stall {
            release: Arc<std::sync::atomic::AtomicBool>,
        }
        impl ProcessLogic for Stall {
            fn step(&mut self, _: u32, _: &mut StepContext) -> crate::error::Result<StepOutcome> {
                if self.release.load(std::sync::atomic::Ordering::Relaxed) {
                    Ok(StepOutcome::Finish(Value::str("done")))
                } else {
                    Ok(StepOutcome::Wait(crate::workflow::process::WaitCondition::Timer(
                        Duration::from_millis(20),
                    )))
                }
            }
            fn save_state(&self) -> Value {
                Value::Null
            }
            fn load_state(&mut self, _: &Value) -> crate::error::Result<()> {
                Ok(())
            }
        }

        let release = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let reg = ProcessRegistry::new();
        {
            let release = Arc::clone(&release);
            reg.register("stall", move || Box::new(Stall { release: Arc::clone(&release) }));
        }

        let doomed_typed =
            Arc::new(RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap());
        let doomed_comm: Arc<dyn Communicator> = Arc::clone(&doomed_typed) as _;
        let doomed = Daemon::start(
            Arc::clone(&doomed_comm),
            Arc::clone(&store),
            reg.clone(),
            DaemonConfig { workers: 1, ..Default::default() },
        )
        .unwrap();

        let client_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let launcher = RemoteLauncher::new(Arc::clone(&client_comm));
        let (_pid, fut) = launcher.launch("stall", Value::Null).unwrap();

        // Give the doomed daemon time to pick the task up, then kill it
        // abruptly: sever its broker connection with the task unacked
        // (the in-process equivalent of `kill -9`).
        std::thread::sleep(Duration::from_millis(200));
        doomed_typed.close();
        drop(doomed); // detaches the stalled worker thread

        // Second daemon; release the stall so it can finish.
        release.store(true, std::sync::atomic::Ordering::Relaxed);
        let survivor_comm: Arc<dyn Communicator> = Arc::new(
            RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap(),
        );
        let survivor = Daemon::start(
            Arc::clone(&survivor_comm),
            Arc::clone(&store),
            reg,
            DaemonConfig { workers: 1, ..Default::default() },
        )
        .unwrap();

        let record = fut.wait(Duration::from_secs(10)).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        survivor.shutdown();
    }
}
