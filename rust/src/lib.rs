//! # kiwi-rs
//!
//! Robust, high-volume messaging for big-data and computational science
//! workflows — a Rust reproduction of the system described in
//! *“kiwiPy: Robust, high-volume, messaging for big-data and computational
//! science workflows”* (Uhrin & Huber, JOSS 2020).
//!
//! kiwiPy exposes three message types — **task queues**, **Remote Procedure
//! Calls** and **broadcasts** — through a single [`communicator::Communicator`],
//! backed by a message broker. This crate rebuilds the complete stack:
//!
//! * [`broker`] — a RabbitMQ-equivalent broker (exchanges, queues, acks,
//!   redelivery, prefetch, TTL, priorities, heartbeat eviction, durable
//!   queues via a write-ahead log, TCP server and in-process transport).
//! * [`communicator`] — the kiwiPy API: `task_send`, `rpc_send`,
//!   `broadcast_send` and their subscriber counterparts, with thread-backed
//!   futures and a hidden communication thread.
//! * [`workflow`] — an AiiDA/plumpy-style process engine: state machine,
//!   checkpoints, pause/play/kill over RPC, parent⇄child decoupling via
//!   broadcasts.
//! * [`daemon`] — the worker pool that consumes the task queue.
//! * [`runtime`] — a PJRT executor that loads AOT-compiled JAX/Pallas
//!   computations (`artifacts/*.hlo.txt`) and runs them as task payloads.
//! * [`baseline`] — the polling-based queue the paper contrasts against.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod baseline;
pub mod benchutil;
pub mod broker;
pub mod cli;
pub mod communicator;
pub mod config;
pub mod daemon;
pub mod error;
pub mod metrics;
pub mod payload;
pub mod proputil;
pub mod runtime;
pub mod transport;
pub mod wire;
pub mod workflow;

pub use error::{Error, Result};
