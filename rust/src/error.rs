//! Unified error type for the whole stack.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error type.
///
/// Variants are grouped by layer so call sites can match on the class of
/// failure (wire corruption vs. broker refusal vs. timeout) without tracking
/// dozens of concrete types.
#[derive(Debug)]
pub enum Error {
    /// Malformed frame / codec data on the wire.
    Wire(String),
    /// Broker-side refusal (unknown queue, exclusive violation, ...).
    Broker(String),
    /// Transport-level I/O failure (socket closed, connect refused, ...).
    Io(std::io::Error),
    /// The remote side for an RPC / task does not exist.
    UnroutableMessage(String),
    /// An RPC handler raised an application error (the remote error text).
    RemoteException(String),
    /// A blocking wait ran out of time.
    Timeout(String),
    /// The communicator / connection has been closed.
    Closed(String),
    /// A duplicate identifier (subscriber id, queue name, ...).
    DuplicateSubscriber(String),
    /// Checkpoint / bundle (de)serialisation failure.
    Persistence(String),
    /// Workflow state machine violation (e.g. play on a finished process).
    InvalidStateTransition { from: String, event: String },
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Configuration / CLI error.
    Config(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Wire(m) => write!(f, "wire error: {m}"),
            Error::Broker(m) => write!(f, "broker error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::UnroutableMessage(m) => write!(f, "unroutable message: {m}"),
            Error::RemoteException(m) => write!(f, "remote exception: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::Closed(m) => write!(f, "closed: {m}"),
            Error::DuplicateSubscriber(m) => write!(f, "duplicate subscriber: {m}"),
            Error::Persistence(m) => write!(f, "persistence error: {m}"),
            Error::InvalidStateTransition { from, event } => {
                write!(f, "invalid state transition: event '{event}' in state '{from}'")
            }
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True when retrying the operation against a live connection may
    /// succeed (transport-level failures), false for logical errors.
    pub fn is_retryable(&self) -> bool {
        matches!(self, Error::Io(_) | Error::Timeout(_) | Error::Closed(_))
    }

    /// Short machine-readable code used on the wire when shipping errors
    /// back to a remote peer.
    pub fn code(&self) -> &'static str {
        match self {
            Error::Wire(_) => "wire",
            Error::Broker(_) => "broker",
            Error::Io(_) => "io",
            Error::UnroutableMessage(_) => "unroutable",
            Error::RemoteException(_) => "remote-exception",
            Error::Timeout(_) => "timeout",
            Error::Closed(_) => "closed",
            Error::DuplicateSubscriber(_) => "duplicate-subscriber",
            Error::Persistence(_) => "persistence",
            Error::InvalidStateTransition { .. } => "invalid-transition",
            Error::Runtime(_) => "runtime",
            Error::Config(_) => "config",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_message() {
        let e = Error::Broker("no such queue 'tasks'".into());
        assert!(e.to_string().contains("no such queue"));
    }

    #[test]
    fn io_errors_are_retryable() {
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "x"));
        assert!(e.is_retryable());
        assert!(!Error::Wire("bad tag".into()).is_retryable());
    }

    #[test]
    fn codes_are_stable() {
        assert_eq!(Error::Timeout("t".into()).code(), "timeout");
        assert_eq!(
            Error::InvalidStateTransition { from: "finished".into(), event: "play".into() }.code(),
            "invalid-transition"
        );
    }
}
