//! The workflow engine — the AiiDA/plumpy analog that exercises all three
//! kiwiPy message types exactly as the paper describes:
//!
//! * **Task queues** (§I.A): processes are submitted to a durable task
//!   queue and consumed by daemon workers; a dead worker's processes are
//!   requeued and resumed *from their checkpoints*.
//! * **RPC** (§I.B): every live process is addressable as `proc.<pid>` and
//!   answers `pause` / `play` / `kill` / `status`.
//! * **Broadcasts** (§I.C): every state change is broadcast as
//!   `state_changed.<pid>.<state>`; parents await children by subscribing
//!   to the child's terminal broadcast — full decoupling, the child never
//!   knows the parent exists.

pub mod checkpoint;
pub mod controller;
pub mod launcher;
pub mod process;
pub mod registry;
pub mod scheduler;
pub mod state;
pub mod workchain;

pub use checkpoint::{
    Bundle, CheckpointStore, FileCheckpointStore, MemoryCheckpointStore, PersistedWait,
};
pub use controller::ProcessController;
pub use launcher::{LaunchRequest, ProcessLauncher, RemoteLauncher};
pub use process::{ProcessLogic, RunOutcome, StepContext, StepEnv, StepOutcome, WaitCondition};
pub use registry::ProcessRegistry;
pub use scheduler::{Scheduler, SchedulerConfig, SchedulerStats};
pub use state::ProcessState;

/// Broadcast subject for a process state change.
pub fn state_subject(pid: &str, state: ProcessState) -> String {
    format!("state_changed.{pid}.{}", state.as_str())
}

/// RPC identifier of a live process.
pub fn process_rpc_id(pid: &str) -> String {
    format!("proc.{pid}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subjects_and_rpc_ids() {
        assert_eq!(state_subject("p1", ProcessState::Finished), "state_changed.p1.finished");
        assert_eq!(process_rpc_id("p1"), "proc.p1");
    }
}
