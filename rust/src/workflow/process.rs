//! The process runner: executes a [`ProcessLogic`] step machine with
//! checkpoints after every step, RPC control (`pause`/`play`/`kill`/
//! `status`), state-change broadcasts, and broadcast-driven waiting on
//! child processes.
//!
//! A *process* here is plumpy's `Process`: a resumable unit of work whose
//! control flow is a sequence of steps. Steps are the checkpoint
//! granularity — exactly like plumpy, where a process can be serialised
//! between (but not during) state transitions.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::communicator::{unique_id, BroadcastFilter, Communicator};
use crate::error::{Error, Result};
use crate::wire::Value;
use crate::workflow::checkpoint::{Bundle, CheckpointStore};
use crate::workflow::state::{ProcessEvent, ProcessState};
use crate::workflow::{process_rpc_id, state_subject};

/// User-implemented process body: a step machine.
pub trait ProcessLogic: Send {
    /// Execute step `step` (0-based). The context gives access to child
    /// spawning and collected child results.
    fn step(&mut self, step: u32, ctx: &mut StepContext) -> Result<StepOutcome>;

    /// Serialise logic-private state into the checkpoint.
    fn save_state(&self) -> Value;

    /// Restore logic-private state from a checkpoint (or from the launch
    /// convention `{"inputs": ...}` for a fresh process).
    fn load_state(&mut self, state: &Value) -> Result<()>;
}

/// What a step decided.
#[derive(Debug)]
pub enum StepOutcome {
    /// Proceed to the next step.
    Continue,
    /// Jump to a specific step (loops).
    Goto(u32),
    /// Park until a condition holds, then re-run from the *next* step.
    Wait(WaitCondition),
    /// Terminal success with outputs.
    Finish(Value),
}

/// Conditions a process can wait on.
#[derive(Clone, Debug)]
pub enum WaitCondition {
    /// All the given child processes reached a terminal state.
    ProcessesTerminated(Vec<String>),
    /// A fixed delay (restarts from zero if resumed from checkpoint —
    /// documented behaviour, DESIGN.md §11 durability notes).
    Timer(Duration),
}

/// Passed to each step.
pub struct StepContext<'a> {
    pub pid: &'a str,
    comm: &'a Arc<dyn Communicator>,
    store: &'a Arc<dyn CheckpointStore>,
    control: &'a Arc<ControlBlock>,
    child_subs: &'a mut Vec<String>,
    /// Task queue children are launched into.
    task_queue: &'a str,
}

impl<'a> StepContext<'a> {
    /// Launch a child process (fire-and-forget: completion is observed via
    /// broadcast / the output record, never via the task reply — the
    /// decoupling §I.C describes). Returns the child pid.
    pub fn spawn(&mut self, process_type: &str, inputs: Value) -> Result<String> {
        let child_pid = unique_id("proc");
        // Subscribe to the child's terminal broadcast BEFORE launching so
        // a fast child cannot slip past us.
        let sub = subscribe_child_terminal(self.comm, self.control, &child_pid)?;
        self.child_subs.push(sub);
        let task = Value::map([
            ("action", Value::str("launch")),
            ("process_type", Value::str(process_type)),
            ("inputs", inputs),
            ("pid", Value::str(&child_pid)),
        ]);
        self.comm.task_send(self.task_queue, task)?;
        Ok(child_pid)
    }

    /// Terminal record of a child (`{state, outputs}`), if known. Checks
    /// broadcasts received so far, then the output store (covers children
    /// that finished while this process was checkpointed).
    pub fn child_result(&self, pid: &str) -> Result<Option<Value>> {
        if let Some(v) = self.control.inner.lock().unwrap().child_events.get(pid) {
            return Ok(Some(v.clone()));
        }
        self.store.load_outputs(pid)
    }

    /// Outputs of a *finished* child; error if it terminated otherwise.
    pub fn child_outputs(&self, pid: &str) -> Result<Value> {
        let record = self.child_result(pid)?.ok_or_else(|| {
            Error::Broker(format!("child '{pid}' has no terminal record yet"))
        })?;
        match record.get_str("state")? {
            "finished" => Ok(record.get("outputs")?.clone()),
            other => Err(Error::RemoteException(format!("child '{pid}' terminated as {other}"))),
        }
    }

    /// Broadcast an application-level message from this process.
    pub fn broadcast(&self, body: Value, subject: &str) -> Result<()> {
        self.comm.broadcast_send(body, Some(self.pid), Some(subject))
    }
}

/// Shared between the runner thread and its RPC/broadcast handlers.
pub(crate) struct ControlBlock {
    inner: Mutex<ControlState>,
    cond: Condvar,
}

#[derive(Default)]
struct ControlState {
    pause_requested: bool,
    kill_requested: Option<String>,
    /// child pid -> terminal record {state, outputs}.
    child_events: BTreeMap<String, Value>,
    /// Mirrors the runner's current state for `status` RPCs.
    status_state: Option<ProcessState>,
    status_step: u32,
}

impl ControlBlock {
    fn new() -> Self {
        ControlBlock { inner: Mutex::new(ControlState::default()), cond: Condvar::new() }
    }
}

fn subscribe_child_terminal(
    comm: &Arc<dyn Communicator>,
    control: &Arc<ControlBlock>,
    child_pid: &str,
) -> Result<String> {
    let control = Arc::clone(control);
    let pid = child_pid.to_string();
    comm.add_broadcast_subscriber(
        BroadcastFilter::all().subject(&format!("state_changed.{child_pid}.*")),
        Box::new(move |msg| {
            let Some(subject) = msg.subject.as_deref() else { return };
            let Some(state_str) = subject.rsplit('.').next() else { return };
            let Ok(state) = ProcessState::parse(state_str) else { return };
            if state.is_terminal() {
                let mut inner = control.inner.lock().unwrap();
                inner.child_events.insert(pid.clone(), msg.body.clone());
                control.cond.notify_all();
            }
        }),
    )
}

/// How a run ended.
#[derive(Clone, Debug, PartialEq)]
pub enum RunOutcome {
    Finished(Value),
    Killed(Option<String>),
    Excepted(String),
}

impl RunOutcome {
    pub fn state(&self) -> ProcessState {
        match self {
            RunOutcome::Finished(_) => ProcessState::Finished,
            RunOutcome::Killed(_) => ProcessState::Killed,
            RunOutcome::Excepted(_) => ProcessState::Excepted,
        }
    }

    /// The terminal record persisted and broadcast: `{state, outputs|reason}`.
    pub fn to_record(&self) -> Value {
        match self {
            RunOutcome::Finished(outputs) => Value::map([
                ("state", Value::str("finished")),
                ("outputs", outputs.clone()),
            ]),
            RunOutcome::Killed(reason) => Value::map([
                ("state", Value::str("killed")),
                ("reason", reason.clone().into()),
            ]),
            RunOutcome::Excepted(msg) => Value::map([
                ("state", Value::str("excepted")),
                ("reason", Value::str(msg)),
            ]),
        }
    }
}

/// Executes one process to termination.
pub struct Runner {
    pid: String,
    process_type: String,
    logic: Box<dyn ProcessLogic>,
    state: ProcessState,
    step: u32,
    comm: Arc<dyn Communicator>,
    store: Arc<dyn CheckpointStore>,
    control: Arc<ControlBlock>,
    child_subs: Vec<String>,
    /// Task queue for spawned children (same queue this process came from).
    task_queue: String,
}

impl Runner {
    /// Fresh process from inputs (launch path). The initial logic state is
    /// the `{"inputs": ...}` convention.
    pub fn launch(
        pid: &str,
        process_type: &str,
        inputs: Value,
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: &crate::workflow::registry::ProcessRegistry,
        task_queue: &str,
    ) -> Result<Self> {
        let mut logic = registry.create(process_type)?;
        logic.load_state(&Value::map([("inputs", inputs)]))?;
        Ok(Self::assemble(pid, process_type, logic, ProcessState::Created, 0, comm, store, task_queue))
    }

    /// Resume from a checkpoint (continue path).
    pub fn from_bundle(
        bundle: &Bundle,
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: &crate::workflow::registry::ProcessRegistry,
        task_queue: &str,
    ) -> Result<Self> {
        if bundle.state.is_terminal() {
            return Err(Error::Persistence(format!(
                "cannot resume terminal process '{}'",
                bundle.pid
            )));
        }
        let mut logic = registry.create(&bundle.process_type)?;
        logic.load_state(&bundle.logic_state)?;
        // A checkpointed Running/Waiting process resumes as Created→Running;
        // Paused stays paused until a `play` RPC.
        let state = match bundle.state {
            ProcessState::Paused => ProcessState::Paused,
            _ => ProcessState::Created,
        };
        Ok(Self::assemble(
            &bundle.pid,
            &bundle.process_type,
            logic,
            state,
            bundle.step,
            comm,
            store,
            task_queue,
        ))
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        pid: &str,
        process_type: &str,
        logic: Box<dyn ProcessLogic>,
        state: ProcessState,
        step: u32,
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        task_queue: &str,
    ) -> Self {
        Runner {
            pid: pid.to_string(),
            process_type: process_type.to_string(),
            logic,
            state,
            step,
            comm,
            store,
            control: Arc::new(ControlBlock::new()),
            child_subs: Vec::new(),
            task_queue: task_queue.to_string(),
        }
    }

    pub fn pid(&self) -> &str {
        &self.pid
    }

    /// Run to termination. Registers the RPC endpoint for the duration,
    /// obeys global `control.all.*` broadcasts (paper §I.C: "sending
    /// pause, play or kill messages to all processes at once"), and
    /// broadcasts every state change.
    pub fn run(mut self) -> Result<RunOutcome> {
        let rpc_id = process_rpc_id(&self.pid);
        self.register_rpc(&rpc_id)?;
        let control_sub = self.register_control_broadcast().ok();
        let outcome = self.run_inner();
        if let Some(sub) = control_sub {
            self.comm.remove_broadcast_subscriber(&sub).ok();
        }
        // Terminal bookkeeping (order matters: record THEN broadcast, so
        // anyone woken by the broadcast finds the record).
        let outcome = match outcome {
            Ok(o) => o,
            Err(e) => RunOutcome::Excepted(e.to_string()),
        };
        let record = outcome.to_record();
        self.store.save_outputs(&self.pid, &record).ok();
        match outcome.state() {
            ProcessState::Finished => {
                self.store.delete(&self.pid).ok();
            }
            _ => {
                // Keep the checkpoint for post-mortem (AiiDA behaviour).
                self.checkpoint().ok();
            }
        }
        self.comm
            .broadcast_send(record, Some(&self.pid), Some(&state_subject(&self.pid, outcome.state())))
            .ok();
        self.comm.remove_rpc_subscriber(&rpc_id).ok();
        for sub in self.child_subs.drain(..) {
            self.comm.remove_broadcast_subscriber(&sub).ok();
        }
        Ok(outcome)
    }

    /// Subscribe to `control.all.<intent>` broadcasts: fleet-wide
    /// pause/play/kill without knowing any pids.
    fn register_control_broadcast(&self) -> Result<String> {
        let control = Arc::clone(&self.control);
        self.comm.add_broadcast_subscriber(
            BroadcastFilter::all().subject("control.all.*"),
            Box::new(move |msg| {
                let Some(subject) = msg.subject.as_deref() else { return };
                let Some(intent) = subject.rsplit('.').next() else { return };
                let mut inner = control.inner.lock().unwrap();
                match intent {
                    "pause" => inner.pause_requested = true,
                    "play" => inner.pause_requested = false,
                    "kill" => {
                        inner.kill_requested =
                            Some("killed by control broadcast".to_string());
                    }
                    _ => return,
                }
                control.cond.notify_all();
            }),
        )
    }

    fn register_rpc(&self, rpc_id: &str) -> Result<()> {
        let control = Arc::clone(&self.control);
        let pid = self.pid.clone();
        self.comm.add_rpc_subscriber(
            rpc_id,
            Box::new(move |msg| {
                let intent = msg.get_str("intent")?;
                let mut inner = control.inner.lock().unwrap();
                match intent {
                    "pause" => {
                        inner.pause_requested = true;
                        control.cond.notify_all();
                        Ok(Value::Bool(true))
                    }
                    "play" => {
                        inner.pause_requested = false;
                        control.cond.notify_all();
                        Ok(Value::Bool(true))
                    }
                    "kill" => {
                        let reason = msg
                            .get_opt("reason")
                            .and_then(|r| r.as_str().ok())
                            .unwrap_or("killed by rpc")
                            .to_string();
                        inner.kill_requested = Some(reason);
                        control.cond.notify_all();
                        Ok(Value::Bool(true))
                    }
                    "status" => Ok(Value::map([
                        ("pid", Value::str(&pid)),
                        (
                            "state",
                            Value::str(
                                inner.status_state.map(|s| s.as_str()).unwrap_or("unknown"),
                            ),
                        ),
                        ("step", Value::from(inner.status_step as u64)),
                    ])),
                    other => Err(Error::RemoteException(format!("unknown intent '{other}'"))),
                }
            }),
        )
    }

    fn transition(&mut self, event: ProcessEvent) -> Result<()> {
        let next = self.state.apply(event)?;
        self.set_state(next);
        Ok(())
    }

    fn set_state(&mut self, next: ProcessState) {
        self.state = next;
        {
            let mut inner = self.control.inner.lock().unwrap();
            inner.status_state = Some(next);
            inner.status_step = self.step;
        }
        // Non-terminal state changes broadcast with an empty body; terminal
        // ones are broadcast by `run` with the full record.
        if !next.is_terminal() {
            self.comm
                .broadcast_send(Value::Null, Some(&self.pid), Some(&state_subject(&self.pid, next)))
                .ok();
        }
    }

    fn checkpoint(&self) -> Result<()> {
        self.store.save(&Bundle {
            pid: self.pid.clone(),
            process_type: self.process_type.clone(),
            state: self.state,
            step: self.step,
            logic_state: self.logic.save_state(),
        })
    }

    fn run_inner(&mut self) -> Result<RunOutcome> {
        // A paused checkpoint stays paused until played.
        if self.state == ProcessState::Paused {
            self.set_state(ProcessState::Paused);
            if let Some(outcome) = self.block_while_paused()? {
                return Ok(outcome);
            }
        } else {
            self.transition(ProcessEvent::Play)?;
        }
        loop {
            // Honour control requests between steps (kill beats pause).
            {
                let inner = self.control.inner.lock().unwrap();
                if let Some(reason) = inner.kill_requested.clone() {
                    drop(inner);
                    self.transition(ProcessEvent::Kill)?;
                    return Ok(RunOutcome::Killed(Some(reason)));
                }
                if inner.pause_requested {
                    drop(inner);
                    self.transition(ProcessEvent::Pause)?;
                    self.checkpoint()?;
                    if let Some(outcome) = self.block_while_paused()? {
                        return Ok(outcome);
                    }
                }
            }

            let step = self.step;
            let outcome = {
                let mut ctx = StepContext {
                    pid: &self.pid,
                    comm: &self.comm,
                    store: &self.store,
                    control: &self.control,
                    child_subs: &mut self.child_subs,
                    task_queue: &self.task_queue,
                };
                // Panic isolation: a buggy step must not take the daemon
                // down; it excepts this process only.
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.logic.step(step, &mut ctx)
                })) {
                    Ok(res) => res,
                    Err(panic) => {
                        let msg = panic
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| panic.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "step panicked".into());
                        self.transition(ProcessEvent::Except).ok();
                        return Ok(RunOutcome::Excepted(msg));
                    }
                }
            };
            match outcome {
                Ok(StepOutcome::Continue) => {
                    self.step += 1;
                    self.checkpoint()?;
                }
                Ok(StepOutcome::Goto(n)) => {
                    self.step = n;
                    self.checkpoint()?;
                }
                Ok(StepOutcome::Wait(cond)) => {
                    self.transition(ProcessEvent::Wait)?;
                    self.step += 1;
                    self.checkpoint()?;
                    if let Some(outcome) = self.block_on_wait(&cond)? {
                        return Ok(outcome);
                    }
                    self.transition(ProcessEvent::Resume)?;
                }
                Ok(StepOutcome::Finish(outputs)) => {
                    self.transition(ProcessEvent::Finish)?;
                    return Ok(RunOutcome::Finished(outputs));
                }
                Err(e) => {
                    self.transition(ProcessEvent::Except).ok();
                    return Ok(RunOutcome::Excepted(e.to_string()));
                }
            }
        }
    }

    /// Park until `play` or `kill`. Returns Some(outcome) on kill.
    fn block_while_paused(&mut self) -> Result<Option<RunOutcome>> {
        loop {
            let inner = self.control.inner.lock().unwrap();
            if let Some(reason) = inner.kill_requested.clone() {
                drop(inner);
                self.transition(ProcessEvent::Kill)?;
                return Ok(Some(RunOutcome::Killed(Some(reason))));
            }
            if !inner.pause_requested {
                drop(inner);
                self.transition(ProcessEvent::Play)?;
                return Ok(None);
            }
            let _unused = self.control.cond.wait_timeout(inner, Duration::from_millis(250)).unwrap();
        }
    }

    /// Park until the wait condition holds. Returns Some(outcome) on kill.
    fn block_on_wait(&mut self, cond: &WaitCondition) -> Result<Option<RunOutcome>> {
        let deadline = match cond {
            WaitCondition::Timer(d) => Some(Instant::now() + *d),
            WaitCondition::ProcessesTerminated(_) => None,
        };
        loop {
            // Check satisfaction.
            match cond {
                WaitCondition::ProcessesTerminated(pids) => {
                    let all_done = {
                        let inner = self.control.inner.lock().unwrap();
                        pids.iter().all(|p| inner.child_events.contains_key(p))
                    };
                    // Fall back to the output store for children that
                    // terminated while we were not listening.
                    let all_done = all_done
                        || pids.iter().all(|p| {
                            let inner = self.control.inner.lock().unwrap();
                            if inner.child_events.contains_key(p) {
                                return true;
                            }
                            drop(inner);
                            match self.store.load_outputs(p) {
                                Ok(Some(rec)) => {
                                    let mut inner = self.control.inner.lock().unwrap();
                                    inner.child_events.insert(p.clone(), rec);
                                    true
                                }
                                _ => false,
                            }
                        });
                    if all_done {
                        return Ok(None);
                    }
                }
                WaitCondition::Timer(_) => {
                    if Instant::now() >= deadline.unwrap() {
                        return Ok(None);
                    }
                }
            }
            let inner = self.control.inner.lock().unwrap();
            if let Some(reason) = inner.kill_requested.clone() {
                drop(inner);
                self.transition(ProcessEvent::Kill)?;
                return Ok(Some(RunOutcome::Killed(Some(reason))));
            }
            // The (guard, timed-out) pair is deliberately discarded: every
            // pass of the loop re-evaluates the wait condition and the kill
            // flag from scratch, so signal, timeout and spurious wakeups are
            // all handled identically. `.unwrap()` still propagates mutex
            // poisoning — nothing is swallowed here.
            let _ = self.control.cond.wait_timeout(inner, Duration::from_millis(50)).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communicator::LocalCommunicator;
    use crate::workflow::checkpoint::MemoryCheckpointStore;
    use crate::workflow::registry::ProcessRegistry;

    /// Counts to `target` one step at a time, recording progress in its
    /// state — the canonical checkpointable process.
    struct Counter {
        target: i64,
        count: i64,
    }

    impl Counter {
        fn boxed() -> Box<dyn ProcessLogic> {
            Box::new(Counter { target: 0, count: 0 })
        }
    }

    impl ProcessLogic for Counter {
        fn step(&mut self, _step: u32, _ctx: &mut StepContext) -> Result<StepOutcome> {
            self.count += 1;
            if self.count >= self.target {
                Ok(StepOutcome::Finish(Value::map([("count", Value::I64(self.count))])))
            } else {
                Ok(StepOutcome::Continue)
            }
        }

        fn save_state(&self) -> Value {
            Value::map([("target", Value::I64(self.target)), ("count", Value::I64(self.count))])
        }

        fn load_state(&mut self, state: &Value) -> Result<()> {
            if let Some(inputs) = state.get_opt("inputs") {
                self.target = inputs.get_i64("target")?;
                self.count = 0;
            } else {
                self.target = state.get_i64("target")?;
                self.count = state.get_i64("count")?;
            }
            Ok(())
        }
    }

    fn setup() -> (Arc<dyn Communicator>, Arc<dyn CheckpointStore>, ProcessRegistry) {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let registry = ProcessRegistry::new();
        registry.register("counter", Counter::boxed);
        (comm, store, registry)
    }

    #[test]
    fn runs_to_finish_with_outputs() {
        let (comm, store, registry) = setup();
        let runner = Runner::launch(
            "p1",
            "counter",
            Value::map([("target", Value::I64(5))]),
            Arc::clone(&comm),
            Arc::clone(&store),
            &registry,
            "tasks",
        )
        .unwrap();
        let outcome = runner.run().unwrap();
        assert_eq!(
            outcome,
            RunOutcome::Finished(Value::map([("count", Value::I64(5))]))
        );
        // Checkpoint removed, outputs record present.
        assert!(store.load("p1").unwrap().is_none());
        let record = store.load_outputs("p1").unwrap().unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
    }

    #[test]
    fn state_changes_are_broadcast() {
        let (comm, store, registry) = setup();
        let (tx, rx) = std::sync::mpsc::channel();
        comm.add_broadcast_subscriber(
            BroadcastFilter::all().subject("state_changed.p2.*"),
            Box::new(move |m| {
                tx.send(m.subject.unwrap()).unwrap();
            }),
        )
        .unwrap();
        let runner = Runner::launch(
            "p2",
            "counter",
            Value::map([("target", Value::I64(1))]),
            Arc::clone(&comm),
            store,
            &registry,
            "tasks",
        )
        .unwrap();
        runner.run().unwrap();
        let subjects: Vec<String> = rx.try_iter().collect();
        assert_eq!(
            subjects,
            vec!["state_changed.p2.running", "state_changed.p2.finished"]
        );
    }

    #[test]
    fn resume_from_checkpoint_continues_not_restarts() {
        let (comm, store, registry) = setup();
        // Run a counter but kill it midway via a kill request injected
        // after 3 steps using a pausing wrapper: simpler — run a fresh
        // runner to create checkpoints, then resurrect from the bundle.
        let runner = Runner::launch(
            "p3",
            "counter",
            Value::map([("target", Value::I64(3))]),
            Arc::clone(&comm),
            Arc::clone(&store),
            &registry,
            "tasks",
        )
        .unwrap();
        runner.run().unwrap();
        // Craft a mid-flight bundle as if the worker died after count=2.
        let bundle = Bundle {
            pid: "p4".into(),
            process_type: "counter".into(),
            state: ProcessState::Running,
            step: 2,
            logic_state: Value::map([("target", Value::I64(5)), ("count", Value::I64(2))]),
        };
        store.save(&bundle).unwrap();
        let resumed =
            Runner::from_bundle(&bundle, Arc::clone(&comm), Arc::clone(&store), &registry, "tasks")
                .unwrap();
        let outcome = resumed.run().unwrap();
        // 3 more steps (not 5): resumed from count=2.
        assert_eq!(outcome, RunOutcome::Finished(Value::map([("count", Value::I64(5))])));
    }

    #[test]
    fn cannot_resume_terminal_bundle() {
        let (comm, store, registry) = setup();
        let bundle = Bundle {
            pid: "pt".into(),
            process_type: "counter".into(),
            state: ProcessState::Finished,
            step: 9,
            logic_state: Value::Null,
        };
        assert!(Runner::from_bundle(&bundle, comm, store, &registry, "tasks").is_err());
    }

    /// Logic that waits on a timer once, then finishes.
    struct Sleeper;
    impl ProcessLogic for Sleeper {
        fn step(&mut self, step: u32, _ctx: &mut StepContext) -> Result<StepOutcome> {
            match step {
                0 => Ok(StepOutcome::Wait(WaitCondition::Timer(Duration::from_millis(30)))),
                _ => Ok(StepOutcome::Finish(Value::str("rested"))),
            }
        }
        fn save_state(&self) -> Value {
            Value::Null
        }
        fn load_state(&mut self, _: &Value) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn timer_wait_then_finish() {
        let (comm, store, registry) = setup();
        registry.register("sleeper", || Box::new(Sleeper));
        let runner =
            Runner::launch("ps", "sleeper", Value::Null, comm, store, &registry, "tasks").unwrap();
        let t0 = Instant::now();
        let outcome = runner.run().unwrap();
        assert_eq!(outcome, RunOutcome::Finished(Value::str("rested")));
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn kill_rpc_interrupts_wait() {
        let (comm, store, registry) = setup();
        registry.register("forever", || {
            struct Forever;
            impl ProcessLogic for Forever {
                fn step(&mut self, _: u32, _: &mut StepContext) -> Result<StepOutcome> {
                    Ok(StepOutcome::Wait(WaitCondition::Timer(Duration::from_secs(3600))))
                }
                fn save_state(&self) -> Value {
                    Value::Null
                }
                fn load_state(&mut self, _: &Value) -> Result<()> {
                    Ok(())
                }
            }
            Box::new(Forever)
        });
        let runner = Runner::launch(
            "pk",
            "forever",
            Value::Null,
            Arc::clone(&comm),
            store,
            &registry,
            "tasks",
        )
        .unwrap();
        let comm2 = Arc::clone(&comm);
        let killer = std::thread::spawn(move || {
            // Wait for the process to be live, then kill it.
            std::thread::sleep(Duration::from_millis(50));
            comm2
                .rpc_send(
                    &process_rpc_id("pk"),
                    Value::map([("intent", Value::str("kill")), ("reason", Value::str("test"))]),
                )
                .unwrap()
                .wait(Duration::from_secs(2))
                .unwrap()
        });
        let outcome = runner.run().unwrap();
        assert_eq!(outcome, RunOutcome::Killed(Some("test".into())));
        assert_eq!(killer.join().unwrap(), Value::Bool(true));
    }

    #[test]
    fn pause_and_play_rpc() {
        let (comm, store, registry) = setup();
        registry.register("pausable", || {
            struct Pausable;
            impl ProcessLogic for Pausable {
                fn step(&mut self, step: u32, _: &mut StepContext) -> Result<StepOutcome> {
                    match step {
                        0 => Ok(StepOutcome::Wait(WaitCondition::Timer(Duration::from_millis(80)))),
                        _ => Ok(StepOutcome::Finish(Value::Null)),
                    }
                }
                fn save_state(&self) -> Value {
                    Value::Null
                }
                fn load_state(&mut self, _: &Value) -> Result<()> {
                    Ok(())
                }
            }
            Box::new(Pausable)
        });
        let runner = Runner::launch(
            "pp",
            "pausable",
            Value::Null,
            Arc::clone(&comm),
            store,
            &registry,
            "tasks",
        )
        .unwrap();
        let comm2 = Arc::clone(&comm);
        let controller = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let rpc = |intent: &str| {
                comm2
                    .rpc_send(
                        &process_rpc_id("pp"),
                        Value::map([("intent", Value::str(intent))]),
                    )
                    .unwrap()
                    .wait(Duration::from_secs(2))
                    .unwrap()
            };
            assert_eq!(rpc("pause"), Value::Bool(true));
            let status = rpc("status");
            assert_eq!(status.get_str("pid").unwrap(), "pp");
            std::thread::sleep(Duration::from_millis(150));
            assert_eq!(rpc("play"), Value::Bool(true));
        });
        let t0 = Instant::now();
        let outcome = runner.run().unwrap();
        controller.join().unwrap();
        assert_eq!(outcome, RunOutcome::Finished(Value::Null));
        // The pause stretched execution beyond the bare 80 ms timer.
        assert!(t0.elapsed() >= Duration::from_millis(150));
    }

    #[test]
    fn panicking_step_excepts_cleanly() {
        let (comm, store, registry) = setup();
        registry.register("bomb", || {
            struct Bomb;
            impl ProcessLogic for Bomb {
                fn step(&mut self, _: u32, _: &mut StepContext) -> Result<StepOutcome> {
                    panic!("kaboom");
                }
                fn save_state(&self) -> Value {
                    Value::Null
                }
                fn load_state(&mut self, _: &Value) -> Result<()> {
                    Ok(())
                }
            }
            Box::new(Bomb)
        });
        let runner = Runner::launch(
            "pb",
            "bomb",
            Value::Null,
            comm,
            Arc::clone(&store),
            &registry,
            "tasks",
        )
        .unwrap();
        match runner.run().unwrap() {
            RunOutcome::Excepted(msg) => assert!(msg.contains("kaboom")),
            other => panic!("expected excepted, got {other:?}"),
        }
        // Terminal record says excepted; checkpoint retained for forensics.
        let record = store.load_outputs("pb").unwrap().unwrap();
        assert_eq!(record.get_str("state").unwrap(), "excepted");
        assert!(store.load("pb").unwrap().is_some());
    }

    #[test]
    fn control_broadcast_kills_all_processes() {
        // Paper §I.C: one broadcast controls every live process.
        let (comm, store, registry) = setup();
        registry.register("waiter", || {
            struct Waiter;
            impl ProcessLogic for Waiter {
                fn step(&mut self, _: u32, _: &mut StepContext) -> Result<StepOutcome> {
                    Ok(StepOutcome::Wait(WaitCondition::Timer(Duration::from_secs(3600))))
                }
                fn save_state(&self) -> Value {
                    Value::Null
                }
                fn load_state(&mut self, _: &Value) -> Result<()> {
                    Ok(())
                }
            }
            Box::new(Waiter)
        });
        let runners: Vec<Runner> = (0..3)
            .map(|i| {
                Runner::launch(
                    &format!("bw{i}"),
                    "waiter",
                    Value::Null,
                    Arc::clone(&comm),
                    Arc::clone(&store),
                    &registry,
                    "tasks",
                )
                .unwrap()
            })
            .collect();
        let comm2 = Arc::clone(&comm);
        let killer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            // One fleet-wide kill, no pids involved.
            comm2
                .broadcast_send(
                    Value::map([("intent", Value::str("kill"))]),
                    None,
                    Some("control.all.kill"),
                )
                .unwrap();
        });
        let handles: Vec<_> =
            runners.into_iter().map(|r| std::thread::spawn(move || r.run().unwrap())).collect();
        for h in handles {
            match h.join().unwrap() {
                RunOutcome::Killed(reason) => {
                    assert!(reason.unwrap().contains("control broadcast"))
                }
                other => panic!("expected killed, got {other:?}"),
            }
        }
        killer.join().unwrap();
    }

    #[test]
    fn rpc_endpoint_removed_after_termination() {
        let (comm, store, registry) = setup();
        let runner = Runner::launch(
            "pr",
            "counter",
            Value::map([("target", Value::I64(1))]),
            Arc::clone(&comm),
            store,
            &registry,
            "tasks",
        )
        .unwrap();
        runner.run().unwrap();
        assert!(matches!(
            comm.rpc_send(&process_rpc_id("pr"), Value::map([("intent", Value::str("status"))])),
            Err(Error::UnroutableMessage(_))
        ));
    }
}
