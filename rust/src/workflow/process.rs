//! The process model: a [`ProcessLogic`] step machine plus the small
//! shared vocabulary (step outcomes, wait conditions, terminal records)
//! the event-driven scheduler executes.
//!
//! A *process* here is plumpy's `Process`: a resumable unit of work whose
//! control flow is a sequence of steps. Steps are the checkpoint
//! granularity — exactly like plumpy, where a process can be serialised
//! between (but not during) state transitions.
//!
//! Since the event-driven refactor this module holds **no thread or
//! blocking code**: steps return [`StepOutcome`]s and the scheduler
//! (`workflow::scheduler`) decides what happens next. A step that waits
//! does not park a thread — the scheduler registers an event subscription
//! or a timer-wheel entry and the worker thread moves on to another
//! process.

use crate::error::{Error, Result};
use crate::wire::Value;
use crate::workflow::state::ProcessState;

/// User-implemented process body: a step machine.
pub trait ProcessLogic: Send {
    /// Execute step `step` (0-based). The context gives access to child
    /// spawning and collected child results.
    fn step(&mut self, step: u32, ctx: &mut StepContext) -> Result<StepOutcome>;

    /// Serialise logic-private state into the checkpoint.
    fn save_state(&self) -> Value;

    /// Restore logic-private state from a checkpoint (or from the launch
    /// convention `{"inputs": ...}` for a fresh process).
    fn load_state(&mut self, state: &Value) -> Result<()>;
}

/// What a step decided.
#[derive(Debug)]
pub enum StepOutcome {
    /// Proceed to the next step.
    Continue,
    /// Jump to a specific step (loops).
    Goto(u32),
    /// Park until a condition holds, then re-run from the *next* step.
    Wait(WaitCondition),
    /// Terminal success with outputs.
    Finish(Value),
}

/// Conditions a process can wait on.
#[derive(Clone, Debug)]
pub enum WaitCondition {
    /// All the given child processes reached a terminal state.
    ProcessesTerminated(Vec<String>),
    /// A fixed delay. The scheduler converts this into an absolute
    /// deadline which is persisted in the checkpoint bundle, so a resume
    /// waits only the *remaining* time (an already-expired deadline
    /// resumes immediately) — elapsed time survives daemon restarts.
    Timer(std::time::Duration),
}

/// The scheduler-side services a step may call. Implemented by the
/// scheduler; indirected through a trait so `ProcessLogic` code depends
/// only on this module.
pub trait StepEnv {
    /// Launch a child process on behalf of `parent`; returns the child pid.
    fn spawn_child(&mut self, parent: &str, process_type: &str, inputs: Value) -> Result<String>;

    /// Terminal record of a child (`{state, outputs}`), if known.
    fn child_result(&self, parent: &str, child: &str) -> Result<Option<Value>>;

    /// Broadcast an application-level message from process `pid`.
    fn broadcast(&self, pid: &str, body: Value, subject: &str) -> Result<()>;
}

/// Passed to each step.
pub struct StepContext<'a> {
    pub pid: &'a str,
    env: &'a mut dyn StepEnv,
}

impl<'a> StepContext<'a> {
    pub fn new(pid: &'a str, env: &'a mut dyn StepEnv) -> Self {
        StepContext { pid, env }
    }

    /// Launch a child process (fire-and-forget: completion is observed via
    /// broadcast / the output record, never via the task reply — the
    /// decoupling §I.C describes). Returns the child pid.
    pub fn spawn(&mut self, process_type: &str, inputs: Value) -> Result<String> {
        self.env.spawn_child(self.pid, process_type, inputs)
    }

    /// Terminal record of a child (`{state, outputs}`), if known. Checks
    /// broadcasts received so far, then the output store (covers children
    /// that finished while this process was checkpointed).
    pub fn child_result(&self, pid: &str) -> Result<Option<Value>> {
        self.env.child_result(self.pid, pid)
    }

    /// Outputs of a *finished* child; error if it terminated otherwise.
    pub fn child_outputs(&self, pid: &str) -> Result<Value> {
        let record = self.child_result(pid)?.ok_or_else(|| {
            Error::Broker(format!("child '{pid}' has no terminal record yet"))
        })?;
        match record.get_str("state")? {
            "finished" => Ok(record.get("outputs")?.clone()),
            other => Err(Error::RemoteException(format!("child '{pid}' terminated as {other}"))),
        }
    }

    /// Broadcast an application-level message from this process.
    pub fn broadcast(&self, body: Value, subject: &str) -> Result<()> {
        self.env.broadcast(self.pid, body, subject)
    }
}

/// How a run ended.
#[derive(Clone, Debug, PartialEq)]
pub enum RunOutcome {
    Finished(Value),
    Killed(Option<String>),
    Excepted(String),
}

impl RunOutcome {
    pub fn state(&self) -> ProcessState {
        match self {
            RunOutcome::Finished(_) => ProcessState::Finished,
            RunOutcome::Killed(_) => ProcessState::Killed,
            RunOutcome::Excepted(_) => ProcessState::Excepted,
        }
    }

    /// The terminal record persisted and broadcast: `{state, outputs|reason}`.
    pub fn to_record(&self) -> Value {
        match self {
            RunOutcome::Finished(outputs) => Value::map([
                ("state", Value::str("finished")),
                ("outputs", outputs.clone()),
            ]),
            RunOutcome::Killed(reason) => Value::map([
                ("state", Value::str("killed")),
                ("reason", reason.clone().into()),
            ]),
            RunOutcome::Excepted(msg) => Value::map([
                ("state", Value::str("excepted")),
                ("reason", Value::str(msg)),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn run_outcome_records() {
        let f = RunOutcome::Finished(Value::map([("x", Value::I64(1))]));
        assert_eq!(f.state(), ProcessState::Finished);
        assert_eq!(f.to_record().get_str("state").unwrap(), "finished");
        assert_eq!(f.to_record().get("outputs").unwrap().get_i64("x").unwrap(), 1);

        let k = RunOutcome::Killed(Some("why".into()));
        assert_eq!(k.state(), ProcessState::Killed);
        assert_eq!(k.to_record().get_str("reason").unwrap(), "why");

        let e = RunOutcome::Excepted("boom".into());
        assert_eq!(e.state(), ProcessState::Excepted);
        assert_eq!(e.to_record().get_str("reason").unwrap(), "boom");
    }

    /// A StepEnv stub: records spawns/broadcasts, serves canned child
    /// results.
    struct FakeEnv {
        spawned: Vec<(String, String)>,
        results: BTreeMap<String, Value>,
        broadcasts: std::cell::RefCell<Vec<String>>,
    }

    impl StepEnv for FakeEnv {
        fn spawn_child(
            &mut self,
            parent: &str,
            process_type: &str,
            _inputs: Value,
        ) -> Result<String> {
            let pid = format!("child-{}", self.spawned.len());
            self.spawned.push((parent.to_string(), process_type.to_string()));
            Ok(pid)
        }
        fn child_result(&self, _parent: &str, child: &str) -> Result<Option<Value>> {
            Ok(self.results.get(child).cloned())
        }
        fn broadcast(&self, _pid: &str, _body: Value, subject: &str) -> Result<()> {
            self.broadcasts.borrow_mut().push(subject.to_string());
            Ok(())
        }
    }

    #[test]
    fn step_context_delegates_to_env() {
        let mut env = FakeEnv {
            spawned: Vec::new(),
            results: BTreeMap::from([(
                "c-ok".to_string(),
                Value::map([
                    ("state", Value::str("finished")),
                    ("outputs", Value::map([("y", Value::I64(7))])),
                ]),
            ), (
                "c-dead".to_string(),
                Value::map([("state", Value::str("killed")), ("reason", Value::Null)]),
            )]),
            broadcasts: std::cell::RefCell::new(Vec::new()),
        };
        let mut ctx = StepContext::new("parent-1", &mut env);
        let child = ctx.spawn("square", Value::Null).unwrap();
        assert_eq!(child, "child-0");
        ctx.broadcast(Value::Null, "app.progress").unwrap();
        assert_eq!(ctx.child_outputs("c-ok").unwrap().get_i64("y").unwrap(), 7);
        // Unknown child: no record yet.
        assert!(ctx.child_result("ghost").unwrap().is_none());
        assert!(ctx.child_outputs("ghost").is_err());
        // Non-finished child: child_outputs errors.
        assert!(matches!(ctx.child_outputs("c-dead"), Err(Error::RemoteException(_))));
        assert_eq!(env.spawned, vec![("parent-1".to_string(), "square".to_string())]);
        assert_eq!(*env.broadcasts.borrow(), vec!["app.progress".to_string()]);
    }
}
