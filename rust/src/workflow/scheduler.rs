//! The event-driven process scheduler: multiplexes an arbitrary number of
//! processes over a **fixed** worker pool.
//!
//! The seed design ran one blocking OS thread per live process and parked
//! it on a condvar for every wait; a daemon's concurrency was its thread
//! count. This scheduler replaces that with a run queue + state machine:
//!
//! ```text
//!            admit (task / local)             step → Continue/Goto
//!                  │                                ┌───────┐
//!                  ▼                                ▼       │
//!   run queue ─▶ Runnable ──worker picks──▶ Stepping ───────┘
//!                  ▲  ▲                      │   │  │
//!                  │  │        Wait(cond)    │   │  └─ Finish/Err/panic
//!      timer fires │  │ child terminal       ▼   ▼           │
//!      or children │  └─────────────────── Waiting  Paused   ▼
//!      all done ───┘                         │ (pause RPC)  Terminal
//!                                            │                (slot
//!                                            ▼                 freed)
//!                              over max_resident_processes?
//!                                 checkpoint + PARK:
//!                          slot freed, resumption re-enters
//!                          through the task queue (max_delivery
//!                          + DLX apply to poison continuations)
//! ```
//!
//! * **No thread ever blocks on a process wait.** `StepOutcome::Wait`
//!   registers either a child-terminal broadcast subscription or a
//!   timer-wheel entry; the worker thread immediately serves the next
//!   runnable pid. Thread count is O(configured workers), never O(live
//!   processes).
//! * **Control RPCs mutate scheduler state.** pause/play/kill set flags on
//!   the slot and enqueue the pid; a worker applies them between steps.
//! * **Long-parked processes release their slot entirely.** Past
//!   `max_resident_processes`, a waiting process is evicted: its
//!   checkpoint (which persists the wait itself, including absolute timer
//!   deadlines) is the only copy; pending task deliveries are completed
//!   with an interim `{state:"waiting", parked:true}` record so they stop
//!   consuming prefetch credit. When the wait resolves, a
//!   `{action:"continue"}` task re-enters the queue and *any* daemon
//!   resumes the process from its checkpoint — poison continuations get
//!   max_delivery + dead-lettering for free, and a daemon or broker
//!   restart resumes the campaign with zero loss.
//!
//! Locking discipline: the engine lock is only ever held for map/flag
//! mutation. Communicator calls (broadcasts, subscriptions, task sends,
//! delivery acks) and checkpoint-store I/O happen on worker threads with
//! the lock released — `LocalCommunicator` delivers callbacks
//! synchronously on the caller thread, so calling it under the lock would
//! deadlock.

use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap, HashSet, VecDeque};
use std::cmp::Reverse;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::communicator::rmq::TaskContext;
use crate::communicator::{unique_id, BroadcastFilter, Communicator};
use crate::daemon::pool::WorkerPool;
use crate::error::{Error, Result};
use crate::wire::Value;
use crate::workflow::checkpoint::{epoch_ms_now, Bundle, CheckpointStore, PersistedWait};
use crate::workflow::launcher::{LaunchRequest, DEFAULT_TASK_QUEUE};
use crate::workflow::process::{ProcessLogic, RunOutcome, StepContext, StepEnv, StepOutcome};
use crate::workflow::registry::ProcessRegistry;
use crate::workflow::state::{ProcessEvent, ProcessState};
use crate::workflow::{process_rpc_id, state_subject};

/// Steps a process may run in one scheduling quantum before yielding the
/// worker to other runnable processes.
const YIELD_AFTER_STEPS: u32 = 64;

/// Scheduler tuning.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Fixed number of step-executor threads.
    pub workers: usize,
    /// Resident-process ceiling: a process entering a wait while more than
    /// this many processes are resident is parked to its checkpoint and
    /// its slot freed (0 = never park).
    pub max_resident: usize,
    /// Task queue children are spawned into and parked processes are
    /// re-enqueued through.
    pub task_queue: String,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            workers: 4,
            max_resident: 1024,
            task_queue: DEFAULT_TASK_QUEUE.into(),
        }
    }
}

/// Counters for observability and benches (monotonic totals plus a
/// point-in-time snapshot of the resident population).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SchedulerStats {
    pub resident: usize,
    pub waiting: usize,
    pub paused: usize,
    pub parked: usize,
    pub run_queue: usize,
    pub admitted_total: u64,
    pub completed_total: u64,
    pub steps_total: u64,
    pub parked_total: u64,
    pub resumed_total: u64,
}

/// Scheduling phase of a resident process (orthogonal to the lifecycle
/// [`ProcessState`]: phase says what the *scheduler* is doing with the
/// slot, lifecycle is the plumpy state machine).
#[derive(Clone, Copy, Debug, PartialEq)]
enum Phase {
    /// In (or eligible for) the run queue.
    Runnable,
    /// A worker is executing steps right now.
    Stepping,
    /// Waiting on children or a timer; wakes by event, not by polling.
    Waiting,
    /// Paused by control; wakes only on play/kill.
    Paused,
}

/// A wait a resident process is parked on.
enum PendingWait {
    Children(BTreeSet<String>),
    Timer { due: Instant, deadline_ms: u64 },
}

impl PendingWait {
    fn to_persisted(&self) -> PersistedWait {
        match self {
            PendingWait::Children(pids) => {
                PersistedWait::Children(pids.iter().cloned().collect())
            }
            PendingWait::Timer { deadline_ms, .. } => {
                PersistedWait::TimerDeadlineMs(*deadline_ms)
            }
        }
    }
}

/// A resident process.
struct Slot {
    process_type: String,
    /// `None` while a worker has the logic checked out for stepping.
    logic: Option<Box<dyn ProcessLogic>>,
    lifecycle: ProcessState,
    step: u32,
    phase: Phase,
    /// Already in the run queue (dedupes wake-ups).
    queued: bool,
    pause_requested: bool,
    kill_requested: Option<String>,
    /// Terminal records of children observed via broadcast / store.
    child_events: BTreeMap<String, Value>,
    awaiting: Option<PendingWait>,
    /// Broadcast subscriptions on child terminals (removed at terminal).
    child_subs: Vec<String>,
    /// Task deliveries to settle with the terminal record.
    deliveries: Vec<TaskContext>,
}

/// A process parked out of residency: checkpoint is the only state; this
/// entry only tracks what must happen for the wake-up.
struct Parked {
    /// True when parked on a children wait (then `pending` empty means
    /// ready); false when parked on a timer (then only `timer_due` wakes).
    waiting_on_children: bool,
    /// Children whose terminal broadcast is still outstanding.
    pending: BTreeSet<String>,
    /// Timer deadline fired (or a wake retry is due).
    timer_due: bool,
    /// The `continue` task has been sent; don't send twice.
    woken: bool,
    deliveries: Vec<TaskContext>,
    child_subs: Vec<String>,
    /// Subscription on our own terminal broadcast (set once woken), so a
    /// resume executed by *another* daemon still settles local watchers.
    terminal_sub: Option<String>,
    /// Own terminal record observed via broadcast.
    record: Option<Value>,
}

enum Admit {
    /// A task-queue message (daemon path): parsed on a worker thread.
    Task(Value, TaskContext),
    /// A locally prepared process (launch/continue API): logic already
    /// constructed and state-loaded, so errors surfaced synchronously.
    Prepared {
        pid: String,
        process_type: String,
        logic: Box<dyn ProcessLogic>,
        bundle: Option<Bundle>,
    },
}

#[derive(Default)]
struct EngineState {
    admits: VecDeque<Admit>,
    run_queue: VecDeque<String>,
    slots: HashMap<String, Slot>,
    parked: HashMap<String, Parked>,
    /// Timer wheel: earliest deadline first. Entries are lazy — stale ones
    /// (paused, already-woken, terminal pids) fire as harmless no-op
    /// wake-ups.
    timers: BinaryHeap<Reverse<(Instant, String)>>,
    /// Pids whose terminal record should be retained for `wait_terminal`.
    watched: HashSet<String>,
    results: HashMap<String, Value>,
}

impl EngineState {
    fn enqueue(&mut self, pid: &str) {
        if let Some(slot) = self.slots.get_mut(pid) {
            if slot.queued {
                return;
            }
            slot.queued = true;
        }
        self.run_queue.push_back(pid.to_string());
    }
}

struct Inner {
    comm: Arc<dyn Communicator>,
    store: Arc<dyn CheckpointStore>,
    registry: ProcessRegistry,
    task_queue: String,
    max_resident: usize,
    state: Mutex<EngineState>,
    /// Wakes worker threads when the run/admit queues gain work.
    work_cv: Condvar,
    /// Wakes the timer thread when the earliest deadline changes.
    timer_cv: Condvar,
    /// Wakes `wait_terminal` callers.
    done_cv: Condvar,
    shutdown: AtomicBool,
    admitted_total: AtomicU64,
    completed_total: AtomicU64,
    steps_total: AtomicU64,
    parked_total: AtomicU64,
    resumed_total: AtomicU64,
}

/// The event-driven scheduler. One per daemon; shared via `Arc`.
pub struct Scheduler {
    inner: Arc<Inner>,
    pool: Mutex<Option<WorkerPool>>,
    timer: Mutex<Option<std::thread::JoinHandle<()>>>,
    control_sub: Mutex<Option<String>>,
}

impl Scheduler {
    /// Start the worker pool, the timer thread and the fleet-wide
    /// `control.all.*` subscription (one per scheduler — pause/play/kill
    /// broadcasts apply to every resident process, paper §I.C).
    pub fn start(
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: ProcessRegistry,
        config: SchedulerConfig,
    ) -> Result<Self> {
        let inner = Arc::new(Inner {
            comm,
            store,
            registry,
            task_queue: config.task_queue.clone(),
            max_resident: config.max_resident,
            state: Mutex::new(EngineState::default()),
            work_cv: Condvar::new(),
            timer_cv: Condvar::new(),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            admitted_total: AtomicU64::new(0),
            completed_total: AtomicU64::new(0),
            steps_total: AtomicU64::new(0),
            parked_total: AtomicU64::new(0),
            resumed_total: AtomicU64::new(0),
        });

        let pool = WorkerPool::new(config.workers, "kiwi-sched");
        // One long-lived loop job per pool thread: the pool provides the
        // fixed, named, panic-isolated threads; the loops provide the
        // scheduling.
        for _ in 0..pool.size() {
            let inner = Arc::clone(&inner);
            pool.submit(move || worker_loop(&inner)).map_err(|()| {
                Error::Runtime("scheduler pool rejected worker loop".into())
            })?;
        }

        let timer = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("kiwi-sched-timer".into())
                .spawn(move || timer_loop(&inner))
                .map_err(|e| Error::Runtime(format!("spawn timer thread: {e}")))?
        };

        let control_sub = {
            let inner = Arc::clone(&inner);
            inner.comm.add_broadcast_subscriber(
                BroadcastFilter::all().subject("control.all.*"),
                Box::new(move |msg| {
                    let Some(subject) = msg.subject.as_deref() else { return };
                    let Some(intent) = subject.rsplit('.').next() else { return };
                    let mut st = inner.state.lock().unwrap();
                    let pids: Vec<String> = st.slots.keys().cloned().collect();
                    for pid in pids {
                        let slot = st.slots.get_mut(&pid).unwrap();
                        match intent {
                            "pause" => slot.pause_requested = true,
                            "play" => slot.pause_requested = false,
                            "kill" => {
                                slot.kill_requested =
                                    Some("killed by control broadcast".to_string())
                            }
                            _ => return,
                        }
                        st.enqueue(&pid);
                    }
                    inner.work_cv.notify_all();
                }),
            )?
        };

        Ok(Scheduler {
            inner,
            pool: Mutex::new(Some(pool)),
            timer: Mutex::new(Some(timer)),
            control_sub: Mutex::new(Some(control_sub)),
        })
    }

    /// Launch a fresh process with a generated pid. The pid is returned
    /// before the process runs; terminal records are retained for
    /// [`Scheduler::wait_terminal`].
    pub fn launch(&self, process_type: &str, inputs: Value) -> Result<String> {
        let pid = unique_id("proc");
        self.launch_with_pid(&pid, process_type, inputs)?;
        Ok(pid)
    }

    /// Launch a fresh process under a caller-chosen pid. Registry and
    /// input errors surface synchronously.
    pub fn launch_with_pid(&self, pid: &str, process_type: &str, inputs: Value) -> Result<()> {
        let mut logic = self.inner.registry.create(process_type)?;
        logic.load_state(&Value::map([("inputs", inputs)]))?;
        self.admit_prepared(Admit::Prepared {
            pid: pid.to_string(),
            process_type: process_type.to_string(),
            logic,
            bundle: None,
        })
    }

    /// Resume a checkpointed process in *this* scheduler (bypassing the
    /// task queue — tests and single-daemon tools). Fails synchronously if
    /// there is no checkpoint or the checkpoint is terminal.
    pub fn continue_local(&self, pid: &str) -> Result<()> {
        let bundle = self
            .inner
            .store
            .load(pid)?
            .ok_or_else(|| Error::Persistence(format!("no checkpoint for '{pid}'")))?;
        if bundle.state.is_terminal() {
            return Err(Error::Persistence(format!(
                "cannot resume terminal process '{pid}'"
            )));
        }
        let mut logic = self.inner.registry.create(&bundle.process_type)?;
        logic.load_state(&bundle.logic_state)?;
        self.admit_prepared(Admit::Prepared {
            pid: pid.to_string(),
            process_type: bundle.process_type.clone(),
            logic,
            bundle: Some(bundle),
        })
    }

    fn admit_prepared(&self, admit: Admit) -> Result<()> {
        let pid = match &admit {
            Admit::Prepared { pid, .. } => pid.clone(),
            Admit::Task(..) => unreachable!("admit_prepared takes Prepared"),
        };
        let mut st = self.inner.state.lock().unwrap();
        st.watched.insert(pid);
        st.admits.push_back(admit);
        self.inner.admitted_total.fetch_add(1, Ordering::Relaxed);
        self.inner.work_cv.notify_all();
        Ok(())
    }

    /// Admit a task-queue message (`{action: launch|continue, ...}`). The
    /// communicator's delivery thread calls this; it only enqueues — all
    /// real work happens on scheduler workers.
    pub fn admit_task(&self, task: Value, ctx: TaskContext) {
        let mut st = self.inner.state.lock().unwrap();
        st.admits.push_back(Admit::Task(task, ctx));
        self.inner.admitted_total.fetch_add(1, Ordering::Relaxed);
        self.inner.work_cv.notify_all();
    }

    /// Mark a pid so its terminal record is retained for
    /// [`Scheduler::wait_terminal`] (locally launched pids are watched
    /// automatically).
    pub fn watch(&self, pid: &str) {
        let mut st = self.inner.state.lock().unwrap();
        st.watched.insert(pid.to_string());
    }

    /// Block until a watched pid reaches a terminal state; returns its
    /// record `{state, outputs|reason}`.
    pub fn wait_terminal(&self, pid: &str, timeout: Duration) -> Result<Value> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        st.watched.insert(pid.to_string());
        loop {
            if let Some(record) = st.results.get(pid) {
                return Ok(record.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Timeout(format!(
                    "process '{pid}' did not reach a terminal state in time"
                )));
            }
            let wait = (deadline - now).min(Duration::from_millis(100));
            st = self.inner.done_cv.wait_timeout(st, wait).unwrap().0;
        }
    }

    /// Re-enqueue every non-terminal checkpoint that has no terminal
    /// record yet through the task queue (recovery after a daemon
    /// restart). Returns how many continue tasks were sent. Explicit
    /// rather than automatic so multi-daemon deployments sharing a store
    /// decide who runs the scan.
    pub fn resume_stored(&self) -> Result<usize> {
        let pids = self.inner.store.list()?;
        let mut sent = 0;
        for pid in pids {
            if self.inner.store.load_outputs(&pid)?.is_some() {
                continue;
            }
            let resident = {
                let st = self.inner.state.lock().unwrap();
                st.slots.contains_key(&pid) || st.parked.contains_key(&pid)
            };
            if resident {
                continue;
            }
            match self.inner.store.load(&pid)? {
                Some(bundle) if !bundle.state.is_terminal() => {
                    self.inner.comm.task_send(
                        &self.inner.task_queue,
                        Value::map([
                            ("action", Value::str("continue")),
                            ("pid", Value::str(&pid)),
                        ]),
                    )?;
                    sent += 1;
                }
                _ => {}
            }
        }
        Ok(sent)
    }

    /// Snapshot of queue depths and monotonic counters.
    pub fn stats(&self) -> SchedulerStats {
        let st = self.inner.state.lock().unwrap();
        SchedulerStats {
            resident: st.slots.len(),
            waiting: st.slots.values().filter(|s| s.phase == Phase::Waiting).count(),
            paused: st.slots.values().filter(|s| s.phase == Phase::Paused).count(),
            parked: st.parked.len(),
            run_queue: st.run_queue.len(),
            admitted_total: self.inner.admitted_total.load(Ordering::Relaxed),
            completed_total: self.inner.completed_total.load(Ordering::Relaxed),
            steps_total: self.inner.steps_total.load(Ordering::Relaxed),
            parked_total: self.inner.parked_total.load(Ordering::Relaxed),
            resumed_total: self.inner.resumed_total.load(Ordering::Relaxed),
        }
    }

    /// Number of step-executor threads.
    pub fn workers(&self) -> usize {
        self.pool.lock().unwrap().as_ref().map(|p| p.size()).unwrap_or(0)
    }

    /// Abrupt stop: signal shutdown and return immediately WITHOUT
    /// joining worker threads (they exit after their current step). Used
    /// by the daemon's drop path to model `kill -9` — unacked deliveries
    /// requeue at the broker.
    pub fn abort(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.work_cv.notify_all();
        self.inner.timer_cv.notify_all();
        self.inner.done_cv.notify_all();
    }

    /// Graceful stop: workers finish their current step and exit; no new
    /// steps start. Safe to call more than once.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.work_cv.notify_all();
        self.inner.timer_cv.notify_all();
        self.inner.done_cv.notify_all();
        if let Some(sub) = self.control_sub.lock().unwrap().take() {
            self.inner.comm.remove_broadcast_subscriber(&sub).ok();
        }
        if let Some(pool) = self.pool.lock().unwrap().take() {
            pool.shutdown();
        }
        if let Some(timer) = self.timer.lock().unwrap().take() {
            timer.join().ok();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        // Abrupt semantics (a killed daemon): signal and detach. Workers
        // exit after their current step; unacked deliveries requeue at the
        // broker. `shutdown()` is the graceful, joining path.
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.work_cv.notify_all();
        self.inner.timer_cv.notify_all();
        self.inner.done_cv.notify_all();
        if let Some(sub) = self.control_sub.lock().unwrap().take() {
            self.inner.comm.remove_broadcast_subscriber(&sub).ok();
        }
        // WorkerPool's Drop detaches; the timer JoinHandle drop detaches.
    }
}

// ---------------------------------------------------------------------------
// Worker threads
// ---------------------------------------------------------------------------

fn worker_loop(inner: &Arc<Inner>) {
    enum Work {
        Admit(Admit),
        Run(String),
    }
    loop {
        let work = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                if let Some(a) = st.admits.pop_front() {
                    break Some(Work::Admit(a));
                }
                if let Some(pid) = st.run_queue.pop_front() {
                    break Some(Work::Run(pid));
                }
                st = inner.work_cv.wait_timeout(st, Duration::from_millis(200)).unwrap().0;
            }
        };
        match work {
            None => return,
            Some(Work::Admit(a)) => do_admit(inner, a),
            Some(Work::Run(pid)) => service(inner, &pid),
        }
    }
}

fn timer_loop(inner: &Arc<Inner>) {
    loop {
        let mut st = inner.state.lock().unwrap();
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        let mut fired = false;
        while let Some(due) = st.timers.peek().map(|Reverse((due, _))| *due) {
            if due > now {
                break;
            }
            let Reverse((_, pid)) = st.timers.pop().unwrap();
            if let Some(slot) = st.slots.get_mut(&pid) {
                // A waiting slot re-checks its condition on service; stale
                // entries (paused, resumed, re-armed) are no-ops there.
                if slot.phase == Phase::Waiting {
                    st.enqueue(&pid);
                    fired = true;
                }
            } else if let Some(p) = st.parked.get_mut(&pid) {
                p.timer_due = true;
                st.run_queue.push_back(pid);
                fired = true;
            }
        }
        if fired {
            inner.work_cv.notify_all();
        }
        let sleep = st
            .timers
            .peek()
            .map(|Reverse((due, _))| due.saturating_duration_since(now))
            .unwrap_or(Duration::from_millis(500))
            .min(Duration::from_millis(500))
            .max(Duration::from_millis(1));
        let _ = inner.timer_cv.wait_timeout(st, sleep).unwrap();
    }
}

// ---------------------------------------------------------------------------
// Admission
// ---------------------------------------------------------------------------

fn do_admit(inner: &Arc<Inner>, admit: Admit) {
    match admit {
        Admit::Prepared { pid, process_type, logic, bundle } => match bundle {
            None => install_fresh(inner, &pid, &process_type, logic, None),
            Some(bundle) => install_resumed(inner, &pid, logic, &bundle, None),
        },
        Admit::Task(task, ctx) => match LaunchRequest::parse(&task) {
            Ok(LaunchRequest::Launch { pid, process_type, inputs }) => {
                admit_launch(inner, &pid, &process_type, inputs, ctx)
            }
            Ok(LaunchRequest::Continue { pid }) => admit_continue(inner, &pid, ctx),
            Err(e) => {
                log::warn!("scheduler: malformed task rejected: {e}");
                ctx.complete(Err(e));
            }
        },
    }
}

fn admit_launch(
    inner: &Arc<Inner>,
    pid: &str,
    process_type: &str,
    inputs: Value,
    ctx: TaskContext,
) {
    // Exactly-once completion for redelivered launches: an already
    // terminal pid answers straight from the output store.
    if let Ok(Some(record)) = inner.store.load_outputs(pid) {
        ctx.complete(Ok(record));
        return;
    }
    {
        let mut st = inner.state.lock().unwrap();
        if let Some(slot) = st.slots.get_mut(pid) {
            slot.deliveries.push(ctx);
            return;
        }
        if let Some(p) = st.parked.get_mut(pid) {
            p.deliveries.push(ctx);
            return;
        }
    }
    // A launch redelivered after a daemon crash resumes from the crashed
    // daemon's checkpoint instead of restarting from step 0.
    match inner.store.load(pid) {
        Ok(Some(bundle)) if !bundle.state.is_terminal() => {
            let mut logic = match inner.registry.create(&bundle.process_type) {
                Ok(l) => l,
                Err(e) => return ctx.complete(Err(e)),
            };
            if let Err(e) = logic.load_state(&bundle.logic_state) {
                return ctx.complete(Err(e));
            }
            install_resumed(inner, pid, logic, &bundle, Some(ctx));
        }
        _ => {
            let mut logic = match inner.registry.create(process_type) {
                Ok(l) => l,
                Err(e) => return ctx.complete(Err(e)),
            };
            if let Err(e) = logic.load_state(&Value::map([("inputs", inputs)])) {
                return ctx.complete(Err(e));
            }
            install_fresh(inner, pid, process_type, logic, Some(ctx));
        }
    }
}

fn admit_continue(inner: &Arc<Inner>, pid: &str, ctx: TaskContext) {
    if let Ok(Some(record)) = inner.store.load_outputs(pid) {
        ctx.complete(Ok(record));
        return;
    }
    // Un-park: our own continue task came back to us — the parked entry's
    // deliveries move onto the revived slot.
    let unparked = {
        let mut st = inner.state.lock().unwrap();
        if let Some(slot) = st.slots.get_mut(pid) {
            slot.deliveries.push(ctx);
            return;
        }
        st.parked.remove(pid)
    };
    if let Some(p) = &unparked {
        // The parked entry's subscriptions are superseded by the ones the
        // resumed slot registers below.
        for sub in &p.child_subs {
            inner.comm.remove_broadcast_subscriber(sub).ok();
        }
        if let Some(sub) = &p.terminal_sub {
            inner.comm.remove_broadcast_subscriber(sub).ok();
        }
    }
    let bundle = match inner.store.load(pid) {
        Ok(Some(b)) => b,
        Ok(None) => {
            // Per-daemon checkpoint stores: hand the task back for a
            // daemon that owns the checkpoint. `max_delivery` turns a
            // checkpoint *nobody* holds into a dead-letter instead of an
            // infinite redelivery loop (the poison-pill path).
            log::warn!("scheduler: no checkpoint for '{pid}' here; returning task to the queue");
            ctx.reject(true);
            return;
        }
        Err(e) => {
            ctx.complete(Err(e));
            return;
        }
    };
    if bundle.state.is_terminal() {
        ctx.complete(Err(Error::Broker(format!(
            "cannot resume terminal process '{pid}'"
        ))));
        return;
    }
    let mut logic = match inner.registry.create(&bundle.process_type) {
        Ok(l) => l,
        Err(e) => return ctx.complete(Err(e)),
    };
    if let Err(e) = logic.load_state(&bundle.logic_state) {
        return ctx.complete(Err(e));
    }
    let mut deliveries = unparked.map(|p| p.deliveries).unwrap_or_default();
    deliveries.push(ctx);
    install_resumed_with_deliveries(inner, pid, logic, &bundle, deliveries);
}

fn install_fresh(
    inner: &Arc<Inner>,
    pid: &str,
    process_type: &str,
    logic: Box<dyn ProcessLogic>,
    ctx: Option<TaskContext>,
) {
    register_rpc(inner, pid);
    let mut st = inner.state.lock().unwrap();
    let slot = Slot {
        process_type: process_type.to_string(),
        logic: Some(logic),
        lifecycle: ProcessState::Created,
        step: 0,
        phase: Phase::Runnable,
        queued: false,
        pause_requested: false,
        kill_requested: None,
        child_events: BTreeMap::new(),
        awaiting: None,
        child_subs: Vec::new(),
        deliveries: ctx.into_iter().collect(),
    };
    st.slots.insert(pid.to_string(), slot);
    st.enqueue(pid);
    inner.work_cv.notify_all();
}

fn install_resumed(
    inner: &Arc<Inner>,
    pid: &str,
    logic: Box<dyn ProcessLogic>,
    bundle: &Bundle,
    ctx: Option<TaskContext>,
) {
    install_resumed_with_deliveries(inner, pid, logic, bundle, ctx.into_iter().collect());
}

fn install_resumed_with_deliveries(
    inner: &Arc<Inner>,
    pid: &str,
    logic: Box<dyn ProcessLogic>,
    bundle: &Bundle,
    deliveries: Vec<TaskContext>,
) {
    inner.resumed_total.fetch_add(1, Ordering::Relaxed);
    register_rpc(inner, pid);

    // Re-arm the persisted wait. Subscriptions go up BEFORE the store is
    // consulted so a child terminating in between is caught by the store
    // query; one terminating after lands in the subscription.
    let mut child_subs = Vec::new();
    let mut awaiting = None;
    let mut pending_children: Vec<String> = Vec::new();
    match &bundle.wait {
        Some(PersistedWait::Children(pids)) => {
            for child in pids {
                if let Ok(sub) = subscribe_child_terminal(inner, pid, child) {
                    child_subs.push(sub);
                }
            }
            pending_children = pids.clone();
            awaiting = Some(PendingWait::Children(pids.iter().cloned().collect()));
        }
        Some(PersistedWait::TimerDeadlineMs(ms)) => {
            // Resume the REMAINING wait: elapsed time survives restarts.
            let remaining = Duration::from_millis(ms.saturating_sub(epoch_ms_now()));
            awaiting = Some(PendingWait::Timer {
                due: Instant::now() + remaining,
                deadline_ms: *ms,
            });
        }
        None => {}
    }

    let (lifecycle, phase, pause_requested) = if bundle.state == ProcessState::Paused {
        // A paused checkpoint stays paused until a play RPC.
        (ProcessState::Paused, Phase::Paused, true)
    } else if awaiting.is_some() {
        (ProcessState::Waiting, Phase::Waiting, false)
    } else {
        (ProcessState::Created, Phase::Runnable, false)
    };

    {
        let mut st = inner.state.lock().unwrap();
        if let Some(PendingWait::Timer { due, .. }) = &awaiting {
            st.timers.push(Reverse((*due, pid.to_string())));
            inner.timer_cv.notify_all();
        }
        let slot = Slot {
            process_type: bundle.process_type.clone(),
            logic: Some(logic),
            lifecycle,
            step: bundle.step,
            phase,
            queued: false,
            pause_requested,
            kill_requested: None,
            child_events: BTreeMap::new(),
            awaiting,
            child_subs,
            deliveries,
        };
        st.slots.insert(pid.to_string(), slot);
        if phase == Phase::Runnable {
            st.enqueue(pid);
        }
        inner.work_cv.notify_all();
    }

    // Children that terminated while this process was checkpointed left
    // their record in the output store; fold those in and wake if done.
    if !pending_children.is_empty() {
        let mut found: Vec<(String, Value)> = Vec::new();
        for child in &pending_children {
            if let Ok(Some(record)) = inner.store.load_outputs(child) {
                found.push((child.clone(), record));
            }
        }
        if !found.is_empty() {
            let mut st = inner.state.lock().unwrap();
            if let Some(slot) = st.slots.get_mut(pid) {
                for (child, record) in found {
                    slot.child_events.insert(child, record);
                }
            }
            st.enqueue(pid);
            inner.work_cv.notify_all();
        }
    }
}

fn register_rpc(inner: &Arc<Inner>, pid: &str) {
    let rpc_inner = Arc::clone(inner);
    let rpc_pid = pid.to_string();
    let result = inner.comm.add_rpc_subscriber(
        &process_rpc_id(pid),
        Box::new(move |msg| {
            let intent = msg.get_str("intent")?.to_string();
            let mut st = rpc_inner.state.lock().unwrap();
            let Some(slot) = st.slots.get_mut(&rpc_pid) else {
                return Err(Error::RemoteException(format!(
                    "process '{rpc_pid}' is not resident"
                )));
            };
            let reply = match intent.as_str() {
                "pause" => {
                    slot.pause_requested = true;
                    Value::Bool(true)
                }
                "play" => {
                    slot.pause_requested = false;
                    Value::Bool(true)
                }
                "kill" => {
                    let reason = msg
                        .get_opt("reason")
                        .and_then(|r| r.as_str().ok())
                        .unwrap_or("killed by rpc")
                        .to_string();
                    slot.kill_requested = Some(reason);
                    Value::Bool(true)
                }
                "status" => Value::map([
                    ("pid", Value::str(&rpc_pid)),
                    ("state", Value::str(slot.lifecycle.as_str())),
                    ("step", Value::I64(slot.step as i64)),
                ]),
                other => {
                    return Err(Error::RemoteException(format!("unknown intent '{other}'")))
                }
            };
            if intent != "status" {
                st.enqueue(&rpc_pid);
                rpc_inner.work_cv.notify_all();
            }
            Ok(reply)
        }),
    );
    if let Err(e) = result {
        log::warn!("scheduler: rpc endpoint for '{pid}': {e}");
    }
}

fn subscribe_child_terminal(inner: &Arc<Inner>, parent: &str, child: &str) -> Result<String> {
    let sub_inner = Arc::clone(inner);
    let parent = parent.to_string();
    let child_pid = child.to_string();
    inner.comm.add_broadcast_subscriber(
        BroadcastFilter::all().subject(&format!("state_changed.{child}.*")),
        Box::new(move |msg| {
            let Some(subject) = msg.subject.as_deref() else { return };
            let Some(state_str) = subject.rsplit('.').next() else { return };
            let Ok(state) = ProcessState::parse(state_str) else { return };
            if !state.is_terminal() {
                return;
            }
            let mut st = sub_inner.state.lock().unwrap();
            if let Some(slot) = st.slots.get_mut(&parent) {
                slot.child_events.insert(child_pid.clone(), msg.body.clone());
                if slot.phase == Phase::Waiting {
                    st.enqueue(&parent);
                }
            } else if let Some(p) = st.parked.get_mut(&parent) {
                p.pending.remove(&child_pid);
                if p.pending.is_empty() && !p.woken {
                    st.run_queue.push_back(parent.clone());
                }
            }
            sub_inner.work_cv.notify_all();
        }),
    )
}

// ---------------------------------------------------------------------------
// Stepping
// ---------------------------------------------------------------------------

/// The scheduler-backed [`StepEnv`] handed to process steps. No engine
/// lock is held while a step runs; each method takes it briefly.
struct SchedEnv<'a> {
    inner: &'a Arc<Inner>,
}

impl StepEnv for SchedEnv<'_> {
    fn spawn_child(&mut self, parent: &str, process_type: &str, inputs: Value) -> Result<String> {
        let child_pid = unique_id("proc");
        // Subscribe to the child's terminal broadcast BEFORE launching so
        // a fast child cannot slip past us.
        let sub = subscribe_child_terminal(self.inner, parent, &child_pid)?;
        {
            let mut st = self.inner.state.lock().unwrap();
            if let Some(slot) = st.slots.get_mut(parent) {
                slot.child_subs.push(sub);
            }
        }
        self.inner.comm.task_send(
            &self.inner.task_queue,
            Value::map([
                ("action", Value::str("launch")),
                ("process_type", Value::str(process_type)),
                ("inputs", inputs),
                ("pid", Value::str(&child_pid)),
            ]),
        )?;
        Ok(child_pid)
    }

    fn child_result(&self, parent: &str, child: &str) -> Result<Option<Value>> {
        {
            let st = self.inner.state.lock().unwrap();
            if let Some(slot) = st.slots.get(parent) {
                if let Some(record) = slot.child_events.get(child) {
                    return Ok(Some(record.clone()));
                }
            }
        }
        self.inner.store.load_outputs(child)
    }

    fn broadcast(&self, pid: &str, body: Value, subject: &str) -> Result<()> {
        self.inner.comm.broadcast_send(body, Some(pid), Some(subject))
    }
}

fn checkpoint(
    inner: &Arc<Inner>,
    pid: &str,
    process_type: &str,
    state: ProcessState,
    step: u32,
    logic: &dyn ProcessLogic,
    wait: Option<PersistedWait>,
) {
    // save_state after a panic may panic again; never let that take the
    // worker down — fall back to a stateless bundle.
    let logic_state =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| logic.save_state()))
            .unwrap_or(Value::Null);
    let bundle = Bundle {
        pid: pid.to_string(),
        process_type: process_type.to_string(),
        state,
        step,
        logic_state,
        wait,
    };
    if let Err(e) = inner.store.save(&bundle) {
        log::warn!("scheduler: checkpoint '{pid}': {e}");
    }
}

fn broadcast_state(inner: &Arc<Inner>, pid: &str, state: ProcessState) {
    // Non-terminal state changes broadcast with an empty body; terminal
    // ones carry the full record (sent by `finalize`).
    inner
        .comm
        .broadcast_send(Value::Null, Some(pid), Some(&state_subject(pid, state)))
        .ok();
}

/// Service one pid popped from the run queue: apply control flags, resolve
/// waits, then run steps to the next wait/terminal (yielding the worker
/// every [`YIELD_AFTER_STEPS`] steps).
fn service(inner: &Arc<Inner>, pid: &str) {
    // Phase A: decide under the lock what to do.
    let mut pending_broadcasts: Vec<ProcessState> = Vec::new();
    let (mut logic, mut step, process_type) = {
        let mut st = inner.state.lock().unwrap();
        let Some(slot) = st.slots.get_mut(pid) else {
            drop(st);
            service_parked(inner, pid);
            return;
        };
        slot.queued = false;
        if slot.phase == Phase::Stepping {
            // Another worker owns it; flags will be honoured between steps.
            return;
        }

        if let Some(reason) = slot.kill_requested.take() {
            slot.phase = Phase::Stepping; // claim: blocks concurrent service
            drop(st);
            finalize(inner, pid, RunOutcome::Killed(Some(reason)), None);
            return;
        }

        if slot.pause_requested {
            if slot.phase == Phase::Paused {
                return; // already parked as paused
            }
            // (Created, Pause) is not a legal edge: play first, like the
            // thread runner did.
            if slot.lifecycle == ProcessState::Created {
                slot.lifecycle = ProcessState::Running;
                pending_broadcasts.push(ProcessState::Running);
            }
            match slot.lifecycle.apply(ProcessEvent::Pause) {
                Ok(next) => slot.lifecycle = next,
                Err(_) => return,
            }
            slot.phase = Phase::Paused;
            pending_broadcasts.push(ProcessState::Paused);
            // Checkpoint the pause (with the wait preserved, so play can
            // re-enter it) outside the lock.
            let ptype = slot.process_type.clone();
            let cstep = slot.step;
            let wait = slot.awaiting.as_ref().map(|w| w.to_persisted());
            let logic_ref = slot.logic.take();
            drop(st);
            for s in &pending_broadcasts {
                broadcast_state(inner, pid, *s);
            }
            if let Some(logic) = logic_ref {
                checkpoint(inner, pid, &ptype, ProcessState::Paused, cstep, logic.as_ref(), wait);
                let mut st = inner.state.lock().unwrap();
                if let Some(slot) = st.slots.get_mut(pid) {
                    slot.logic = Some(logic);
                }
                // A play/kill may have arrived while we checkpointed; a
                // re-service is cheap and re-checks everything.
                st.enqueue(pid);
                inner.work_cv.notify_all();
            }
            return;
        }

        if slot.phase == Phase::Paused {
            // play: Paused → Running, then back into the wait if one is
            // still unsatisfied.
            if slot.logic.is_none() {
                // The pausing worker still has the logic checked out for
                // its checkpoint; it re-enqueues us when done.
                return;
            }
            match slot.lifecycle.apply(ProcessEvent::Play) {
                Ok(next) => slot.lifecycle = next,
                Err(_) => return,
            }
            pending_broadcasts.push(ProcessState::Running);
            let satisfied = match &slot.awaiting {
                Some(aw) => wait_satisfied(aw, &slot.child_events),
                None => true,
            };
            if satisfied {
                slot.awaiting = None;
                slot.phase = Phase::Runnable;
            } else {
                slot.lifecycle = ProcessState::Waiting;
                slot.phase = Phase::Waiting;
                pending_broadcasts.push(ProcessState::Waiting);
                let timer_due = match &slot.awaiting {
                    Some(PendingWait::Timer { due, .. }) => Some(*due),
                    _ => None,
                };
                if let Some(due) = timer_due {
                    st.timers.push(Reverse((due, pid.to_string())));
                    inner.timer_cv.notify_all();
                }
                drop(st);
                for s in &pending_broadcasts {
                    broadcast_state(inner, pid, *s);
                }
                return;
            }
        }

        if slot.phase == Phase::Waiting {
            let satisfied = match &slot.awaiting {
                Some(aw) => wait_satisfied(aw, &slot.child_events),
                None => true,
            };
            if !satisfied {
                // Children may have terminated while we were deaf (e.g.
                // before our subscription went up): consult the output
                // store for the missing ones, outside the lock.
                let missing: Vec<String> = match &slot.awaiting {
                    Some(PendingWait::Children(pids)) => pids
                        .iter()
                        .filter(|p| !slot.child_events.contains_key(*p))
                        .cloned()
                        .collect(),
                    _ => return, // timer not due yet: spurious wake
                };
                drop(st);
                let mut found = Vec::new();
                for child in &missing {
                    if let Ok(Some(record)) = inner.store.load_outputs(child) {
                        found.push((child.clone(), record));
                    }
                }
                if found.is_empty() {
                    return; // genuinely still waiting
                }
                let mut st2 = inner.state.lock().unwrap();
                let Some(slot) = st2.slots.get_mut(pid) else { return };
                for (child, record) in found {
                    slot.child_events.insert(child, record);
                }
                let now_satisfied = match &slot.awaiting {
                    Some(aw) => wait_satisfied(aw, &slot.child_events),
                    None => true,
                };
                if !now_satisfied {
                    return;
                }
                st2.enqueue(pid);
                inner.work_cv.notify_all();
                return; // re-serviced with the wait satisfied
            }
            match slot.lifecycle.apply(ProcessEvent::Resume) {
                Ok(next) => slot.lifecycle = next,
                Err(_) => return,
            }
            slot.awaiting = None;
            slot.phase = Phase::Runnable;
            pending_broadcasts.push(ProcessState::Running);
        }

        if slot.lifecycle == ProcessState::Created {
            match slot.lifecycle.apply(ProcessEvent::Play) {
                Ok(next) => slot.lifecycle = next,
                Err(_) => return,
            }
            pending_broadcasts.push(ProcessState::Running);
        }

        // Check the logic out for stepping.
        let Some(logic) = slot.logic.take() else { return };
        slot.phase = Phase::Stepping;
        (logic, slot.step, slot.process_type.clone())
    };

    for s in &pending_broadcasts {
        broadcast_state(inner, pid, *s);
    }

    // Phase B: run steps to completion, lock released.
    let mut steps_this_quantum = 0u32;
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            park_runnable(inner, pid, logic);
            return;
        }
        let outcome = {
            let mut env = SchedEnv { inner };
            let mut ctx = StepContext::new(pid, &mut env);
            // Panic isolation: a buggy step must not take the daemon
            // down; it excepts this process only.
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                logic.step(step, &mut ctx)
            })) {
                Ok(res) => res,
                Err(panic) => {
                    let msg = panic
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| panic.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "step panicked".into());
                    finalize(inner, pid, RunOutcome::Excepted(msg), Some(logic));
                    return;
                }
            }
        };
        inner.steps_total.fetch_add(1, Ordering::Relaxed);
        steps_this_quantum += 1;
        match outcome {
            Ok(StepOutcome::Continue) | Ok(StepOutcome::Goto(_)) => {
                step = match outcome {
                    Ok(StepOutcome::Goto(n)) => n,
                    _ => step + 1,
                };
                checkpoint(
                    inner,
                    pid,
                    &process_type,
                    ProcessState::Running,
                    step,
                    logic.as_ref(),
                    None,
                );
                let must_yield = {
                    let mut st = inner.state.lock().unwrap();
                    let Some(slot) = st.slots.get_mut(pid) else { return };
                    slot.step = step;
                    slot.kill_requested.is_some()
                        || slot.pause_requested
                        || steps_this_quantum >= YIELD_AFTER_STEPS
                };
                if must_yield {
                    park_runnable(inner, pid, logic);
                    return;
                }
            }
            Ok(StepOutcome::Wait(cond)) => {
                handle_wait(inner, pid, &process_type, step, logic, cond);
                return;
            }
            Ok(StepOutcome::Finish(outputs)) => {
                finalize(inner, pid, RunOutcome::Finished(outputs), Some(logic));
                return;
            }
            Err(e) => {
                finalize(inner, pid, RunOutcome::Excepted(e.to_string()), Some(logic));
                return;
            }
        }
    }
}

/// Return a checked-out logic to its slot and requeue the pid (control
/// flags pending, quantum expired, or shutdown).
fn park_runnable(inner: &Arc<Inner>, pid: &str, logic: Box<dyn ProcessLogic>) {
    let mut st = inner.state.lock().unwrap();
    if let Some(slot) = st.slots.get_mut(pid) {
        slot.logic = Some(logic);
        slot.phase = Phase::Runnable;
        st.enqueue(pid);
        inner.work_cv.notify_all();
    }
}

fn wait_satisfied(aw: &PendingWait, events: &BTreeMap<String, Value>) -> bool {
    match aw {
        PendingWait::Children(pids) => pids.iter().all(|p| events.contains_key(p)),
        PendingWait::Timer { due, .. } => Instant::now() >= *due,
    }
}

/// A step returned `Wait`: transition to Waiting, checkpoint with the
/// persisted wait (absolute timer deadline — satellite of the restart-
/// losing-elapsed-time fix), arm the wake-up, and maybe park the process
/// out of residency entirely.
fn handle_wait(
    inner: &Arc<Inner>,
    pid: &str,
    process_type: &str,
    step: u32,
    logic: Box<dyn ProcessLogic>,
    cond: crate::workflow::process::WaitCondition,
) {
    use crate::workflow::process::WaitCondition;
    let next_step = step + 1;
    let pending = match cond {
        WaitCondition::Timer(d) => PendingWait::Timer {
            due: Instant::now() + d,
            deadline_ms: epoch_ms_now() + d.as_millis() as u64,
        },
        WaitCondition::ProcessesTerminated(pids) => {
            PendingWait::Children(pids.into_iter().collect())
        }
    };
    checkpoint(
        inner,
        pid,
        process_type,
        ProcessState::Waiting,
        next_step,
        logic.as_ref(),
        Some(pending.to_persisted()),
    );

    let mut to_evict = false;
    {
        let mut st = inner.state.lock().unwrap();
        let Some(slot) = st.slots.get_mut(pid) else { return };
        slot.step = next_step;
        if let Some(reason) = slot.kill_requested.take() {
            drop(st);
            finalize(inner, pid, RunOutcome::Killed(Some(reason)), Some(logic));
            return;
        }
        if let Ok(next) = slot.lifecycle.apply(ProcessEvent::Wait) {
            slot.lifecycle = next;
        }
        slot.phase = Phase::Waiting;
        let satisfied = wait_satisfied(&pending, &slot.child_events);
        if let PendingWait::Timer { due, .. } = &pending {
            if !satisfied {
                st.timers.push(Reverse((*due, pid.to_string())));
                inner.timer_cv.notify_all();
            }
        }
        let Some(slot) = st.slots.get_mut(pid) else { return };
        slot.awaiting = Some(pending);
        slot.logic = Some(logic);
        if satisfied {
            st.enqueue(pid);
            inner.work_cv.notify_all();
        } else if inner.max_resident > 0 && st.slots.len() > inner.max_resident {
            to_evict = true;
        }
    }
    broadcast_state(inner, pid, ProcessState::Waiting);
    if to_evict {
        evict(inner, pid);
    }
}

/// Park a waiting process out of residency: the checkpoint (already
/// written, wait included) becomes the only copy. Its task deliveries are
/// completed with an interim record so they stop consuming the consumer's
/// prefetch credit; the terminal record remains observable via the output
/// store and the terminal broadcast.
fn evict(inner: &Arc<Inner>, pid: &str) {
    let deliveries = {
        let mut st = inner.state.lock().unwrap();
        let Some(slot) = st.slots.get(pid) else { return };
        // Only evict if still quietly waiting (no control flags pending).
        if slot.phase != Phase::Waiting
            || slot.kill_requested.is_some()
            || slot.pause_requested
        {
            return;
        }
        let mut slot = st.slots.remove(pid).unwrap();
        let waiting_on_children =
            matches!(&slot.awaiting, Some(PendingWait::Children(_)));
        let pending = match &slot.awaiting {
            Some(PendingWait::Children(pids)) => pids
                .iter()
                .filter(|p| !slot.child_events.contains_key(*p))
                .cloned()
                .collect(),
            _ => BTreeSet::new(),
        };
        let deliveries = std::mem::take(&mut slot.deliveries);
        let parked = Parked {
            waiting_on_children,
            pending,
            timer_due: false,
            woken: false,
            deliveries: Vec::new(),
            child_subs: std::mem::take(&mut slot.child_subs),
            terminal_sub: None,
            record: None,
        };
        st.parked.insert(pid.to_string(), parked);
        inner.parked_total.fetch_add(1, Ordering::Relaxed);
        deliveries
    };
    // Parked processes are not RPC-addressable (there is nothing resident
    // to control); the endpoint returns when the process resumes.
    inner.comm.remove_rpc_subscriber(&process_rpc_id(pid)).ok();
    let interim = Value::map([
        ("state", Value::str("waiting")),
        ("parked", Value::Bool(true)),
    ]);
    for ctx in deliveries {
        ctx.complete(Ok(interim.clone()));
    }
    // If the wait resolved while we were evicting, wake immediately.
    let wake_now = {
        let mut st = inner.state.lock().unwrap();
        match st.parked.get(pid) {
            Some(p) if p.waiting_on_children && p.pending.is_empty() && !p.woken => {
                st.run_queue.push_back(pid.to_string());
                true
            }
            _ => false,
        }
    };
    if wake_now {
        inner.work_cv.notify_all();
    }
}

/// Service a pid that has no slot: either a parked process whose wake-up
/// or terminal record arrived, or a stale queue entry for a terminated
/// process (no-op).
fn service_parked(inner: &Arc<Inner>, pid: &str) {
    // Terminal record observed (a continue consumed elsewhere finished):
    // settle and drop the parked entry.
    let settled = {
        let mut st = inner.state.lock().unwrap();
        match st.parked.get(pid) {
            Some(p) if p.record.is_some() => st.parked.remove(pid),
            _ => None,
        }
    };
    if let Some(p) = settled {
        let record = p.record.clone().unwrap_or(Value::Null);
        for ctx in p.deliveries {
            ctx.complete(Ok(record.clone()));
        }
        for sub in &p.child_subs {
            inner.comm.remove_broadcast_subscriber(sub).ok();
        }
        if let Some(sub) = &p.terminal_sub {
            inner.comm.remove_broadcast_subscriber(sub).ok();
        }
        record_result(inner, pid, record);
        return;
    }

    // Wake-up: wait resolved (children done or timer due) and no continue
    // task sent yet.
    let should_wake = {
        let mut st = inner.state.lock().unwrap();
        match st.parked.get_mut(pid) {
            Some(p)
                if ((p.waiting_on_children && p.pending.is_empty()) || p.timer_due)
                    && !p.woken =>
            {
                p.woken = true;
                true
            }
            _ => false,
        }
    };
    if !should_wake {
        return;
    }
    // Watch for our own terminal BEFORE sending the continue, so a resume
    // on another daemon cannot finish unseen.
    let sub = {
        let sub_inner = Arc::clone(inner);
        let own = pid.to_string();
        inner.comm.add_broadcast_subscriber(
            BroadcastFilter::all().subject(&format!("state_changed.{pid}.*")),
            Box::new(move |msg| {
                let Some(subject) = msg.subject.as_deref() else { return };
                let Some(state_str) = subject.rsplit('.').next() else { return };
                let Ok(state) = ProcessState::parse(state_str) else { return };
                if !state.is_terminal() {
                    return;
                }
                let mut st = sub_inner.state.lock().unwrap();
                if let Some(p) = st.parked.get_mut(&own) {
                    p.record = Some(msg.body.clone());
                    st.run_queue.push_back(own.clone());
                    sub_inner.work_cv.notify_all();
                }
            }),
        )
    };
    let send = inner.comm.task_send(
        &inner.task_queue,
        Value::map([("action", Value::str("continue")), ("pid", Value::str(pid))]),
    );
    let mut st = inner.state.lock().unwrap();
    match st.parked.get_mut(pid) {
        Some(p) => {
            p.terminal_sub = sub.ok();
            if let Err(e) = send {
                // Broker unreachable: retry through the timer wheel (the
                // reconnect layer usually heals the communicator first).
                log::warn!("scheduler: wake '{pid}': {e}; retrying");
                p.woken = false;
                p.timer_due = true;
                st.timers
                    .push(Reverse((Instant::now() + Duration::from_millis(500), pid.to_string())));
                inner.timer_cv.notify_all();
            }
        }
        None => {
            // Our continue task was admitted synchronously and already
            // unparked the pid; the slot owns settling now.
            drop(st);
            if let Ok(s) = sub {
                inner.comm.remove_broadcast_subscriber(&s).ok();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Termination
// ---------------------------------------------------------------------------

fn record_result(inner: &Arc<Inner>, pid: &str, record: Value) {
    inner.completed_total.fetch_add(1, Ordering::Relaxed);
    let mut st = inner.state.lock().unwrap();
    if st.watched.contains(pid) {
        st.results.insert(pid.to_string(), record);
    }
    inner.done_cv.notify_all();
}

/// Terminal bookkeeping, in the order the thread runner used: outputs
/// record first, THEN the terminal broadcast (so anyone woken by the
/// broadcast finds the record), then delivery completion and endpoint
/// teardown.
fn finalize(
    inner: &Arc<Inner>,
    pid: &str,
    outcome: RunOutcome,
    logic: Option<Box<dyn ProcessLogic>>,
) {
    let (slot_logic, step, process_type, deliveries, child_subs) = {
        let mut st = inner.state.lock().unwrap();
        let Some(mut slot) = st.slots.remove(pid) else { return };
        (
            slot.logic.take(),
            slot.step,
            slot.process_type.clone(),
            std::mem::take(&mut slot.deliveries),
            std::mem::take(&mut slot.child_subs),
        )
    };
    let logic = logic.or(slot_logic);
    let record = outcome.to_record();
    inner.store.save_outputs(pid, &record).ok();
    match outcome.state() {
        ProcessState::Finished => {
            inner.store.delete(pid).ok();
        }
        state => {
            // Keep a terminal checkpoint for post-mortem (AiiDA behaviour).
            if let Some(logic) = &logic {
                checkpoint(inner, pid, &process_type, state, step, logic.as_ref(), None);
            }
        }
    }
    inner
        .comm
        .broadcast_send(record.clone(), Some(pid), Some(&state_subject(pid, outcome.state())))
        .ok();
    for ctx in deliveries {
        ctx.complete(Ok(record.clone()));
    }
    inner.comm.remove_rpc_subscriber(&process_rpc_id(pid)).ok();
    for sub in child_subs {
        inner.comm.remove_broadcast_subscriber(&sub).ok();
    }
    record_result(inner, pid, record);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communicator::LocalCommunicator;
    use crate::workflow::checkpoint::MemoryCheckpointStore;
    use crate::workflow::controller::ProcessController;
    use crate::workflow::process::WaitCondition;

    /// Finishes immediately with `{sum: a+b}`.
    struct Adder {
        a: i64,
        b: i64,
    }
    impl ProcessLogic for Adder {
        fn step(&mut self, _: u32, _: &mut StepContext) -> Result<StepOutcome> {
            Ok(StepOutcome::Finish(Value::map([("sum", Value::I64(self.a + self.b))])))
        }
        fn save_state(&self) -> Value {
            Value::map([("a", Value::I64(self.a)), ("b", Value::I64(self.b))])
        }
        fn load_state(&mut self, state: &Value) -> Result<()> {
            let src = state.get_opt("inputs").unwrap_or(state);
            self.a = src.get_i64("a")?;
            self.b = src.get_i64("b")?;
            Ok(())
        }
    }

    /// Finishes with the step number it actually ran at (proves resumes
    /// continue, not restart).
    struct Tally;
    impl ProcessLogic for Tally {
        fn step(&mut self, step: u32, _: &mut StepContext) -> Result<StepOutcome> {
            Ok(StepOutcome::Finish(Value::map([("resumed_at", Value::I64(step as i64))])))
        }
        fn save_state(&self) -> Value {
            Value::map([])
        }
        fn load_state(&mut self, _: &Value) -> Result<()> {
            Ok(())
        }
    }

    /// step 0: wait `ms`; step 1: finish.
    struct Napper {
        ms: u64,
    }
    impl ProcessLogic for Napper {
        fn step(&mut self, step: u32, _: &mut StepContext) -> Result<StepOutcome> {
            match step {
                0 => Ok(StepOutcome::Wait(WaitCondition::Timer(Duration::from_millis(self.ms)))),
                _ => Ok(StepOutcome::Finish(Value::map([("woke", Value::Bool(true))]))),
            }
        }
        fn save_state(&self) -> Value {
            Value::map([("ms", Value::I64(self.ms as i64))])
        }
        fn load_state(&mut self, state: &Value) -> Result<()> {
            let src = state.get_opt("inputs").unwrap_or(state);
            if let Some(ms) = src.get_opt("ms") {
                self.ms = ms.as_i64()? as u64;
            }
            Ok(())
        }
    }

    struct Bomb;
    impl ProcessLogic for Bomb {
        fn step(&mut self, _: u32, _: &mut StepContext) -> Result<StepOutcome> {
            panic!("kaboom");
        }
        fn save_state(&self) -> Value {
            Value::map([])
        }
        fn load_state(&mut self, _: &Value) -> Result<()> {
            Ok(())
        }
    }

    fn registry() -> ProcessRegistry {
        let r = ProcessRegistry::new();
        r.register("adder", || Box::new(Adder { a: 0, b: 0 }));
        r.register("tally", || Box::new(Tally));
        r.register("napper", || Box::new(Napper { ms: 50 }));
        r.register("bomb", || Box::new(Bomb));
        r
    }

    struct Stack {
        comm: Arc<dyn Communicator>,
        store: Arc<MemoryCheckpointStore>,
        sched: Arc<Scheduler>,
    }

    fn stack(workers: usize, max_resident: usize) -> Stack {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store = Arc::new(MemoryCheckpointStore::new());
        let sched = Scheduler::start(
            Arc::clone(&comm),
            store.clone() as Arc<dyn CheckpointStore>,
            registry(),
            SchedulerConfig { workers, max_resident, ..SchedulerConfig::default() },
        )
        .unwrap();
        Stack { comm, store, sched: Arc::new(sched) }
    }

    /// Consume the task queue back into the scheduler itself (what a
    /// daemon does) — needed whenever parked processes must resume.
    fn self_consume(s: &Stack) {
        let sched = Arc::clone(&s.sched);
        s.comm
            .task_queue(
                DEFAULT_TASK_QUEUE,
                0,
                Box::new(move |task, ctx| sched.admit_task(task, ctx)),
            )
            .unwrap();
    }

    const WAIT: Duration = Duration::from_secs(10);

    #[test]
    fn runs_to_finish_with_outputs() {
        let s = stack(2, 0);
        let pid = s
            .sched
            .launch("adder", Value::map([("a", Value::I64(2)), ("b", Value::I64(40))]))
            .unwrap();
        let record = s.sched.wait_terminal(&pid, WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert_eq!(record.get("outputs").unwrap().get_i64("sum").unwrap(), 42);
        // Finished processes leave an outputs record but no checkpoint.
        assert!(s.store.load_outputs(&pid).unwrap().is_some());
        assert!(s.store.load(&pid).unwrap().is_none());
        s.sched.shutdown();
    }

    #[test]
    fn state_changes_are_broadcast() {
        let s = stack(1, 0);
        let (tx, rx) = std::sync::mpsc::channel();
        s.comm
            .add_broadcast_subscriber(
                BroadcastFilter::all().subject("state_changed.p2.*"),
                Box::new(move |m| {
                    tx.send(m.subject.unwrap()).ok();
                }),
            )
            .unwrap();
        s.sched
            .launch_with_pid(
                "p2",
                "adder",
                Value::map([("a", Value::I64(1)), ("b", Value::I64(1))]),
            )
            .unwrap();
        let record = s.sched.wait_terminal("p2", WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        let subjects: Vec<String> = rx.try_iter().collect();
        assert_eq!(
            subjects,
            vec!["state_changed.p2.running".to_string(), "state_changed.p2.finished".to_string()]
        );
        s.sched.shutdown();
    }

    #[test]
    fn resume_from_checkpoint_continues_not_restarts() {
        let s = stack(2, 0);
        s.store
            .save(&Bundle {
                pid: "r1".into(),
                process_type: "tally".into(),
                state: ProcessState::Running,
                step: 3,
                logic_state: Value::map([]),
                wait: None,
            })
            .unwrap();
        s.sched.continue_local("r1").unwrap();
        let record = s.sched.wait_terminal("r1", WAIT).unwrap();
        assert_eq!(record.get("outputs").unwrap().get_i64("resumed_at").unwrap(), 3);
        s.sched.shutdown();
    }

    #[test]
    fn cannot_resume_terminal_bundle() {
        let s = stack(1, 0);
        s.store
            .save(&Bundle {
                pid: "dead".into(),
                process_type: "tally".into(),
                state: ProcessState::Killed,
                step: 1,
                logic_state: Value::map([]),
                wait: None,
            })
            .unwrap();
        assert!(s.sched.continue_local("dead").is_err());
        assert!(s.sched.continue_local("ghost").is_err());
        s.sched.shutdown();
    }

    #[test]
    fn timer_wait_then_finish() {
        let s = stack(2, 0);
        let t0 = Instant::now();
        let pid = s
            .sched
            .launch("napper", Value::map([("ms", Value::I64(60))]))
            .unwrap();
        let record = s.sched.wait_terminal(&pid, WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert!(t0.elapsed() >= Duration::from_millis(60), "timer must actually wait");
        s.sched.shutdown();
    }

    /// Satellite regression: a checkpointed timer wait persists its
    /// absolute deadline, so a resume waits only the REMAINING time — and
    /// an already-expired deadline resumes immediately.
    #[test]
    fn timer_resume_waits_only_remaining_time() {
        let s = stack(2, 0);
        // Pretend the process entered a long (10 s) wait some time ago:
        // only ~200 ms remain.
        s.store
            .save(&Bundle {
                pid: "t-rem".into(),
                process_type: "napper".into(),
                state: ProcessState::Waiting,
                step: 1,
                logic_state: Value::map([("ms", Value::I64(10_000))]),
                wait: Some(PersistedWait::TimerDeadlineMs(epoch_ms_now() + 200)),
            })
            .unwrap();
        let t0 = Instant::now();
        s.sched.continue_local("t-rem").unwrap();
        let record = s.sched.wait_terminal("t-rem", WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        let elapsed = t0.elapsed();
        assert!(elapsed >= Duration::from_millis(150), "must wait the remaining time");
        assert!(elapsed < Duration::from_secs(5), "must NOT restart the full 10 s wait");

        // Deadline already passed while checkpointed: resume immediately.
        s.store
            .save(&Bundle {
                pid: "t-exp".into(),
                process_type: "napper".into(),
                state: ProcessState::Waiting,
                step: 1,
                logic_state: Value::map([("ms", Value::I64(10_000))]),
                wait: Some(PersistedWait::TimerDeadlineMs(epoch_ms_now().saturating_sub(5_000))),
            })
            .unwrap();
        let t1 = Instant::now();
        s.sched.continue_local("t-exp").unwrap();
        let record = s.sched.wait_terminal("t-exp", WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert!(t1.elapsed() < Duration::from_secs(5), "expired deadline resumes at once");
        s.sched.shutdown();
    }

    #[test]
    fn kill_rpc_interrupts_wait() {
        let s = stack(2, 0);
        let (tx, rx) = std::sync::mpsc::channel();
        let pid = {
            let pid = unique_id("proc");
            s.comm
                .add_broadcast_subscriber(
                    BroadcastFilter::all().subject(&format!("state_changed.{pid}.waiting")),
                    Box::new(move |_| {
                        tx.send(()).ok();
                    }),
                )
                .unwrap();
            s.sched
                .launch_with_pid(&pid, "napper", Value::map([("ms", Value::I64(60_000))]))
                .unwrap();
            pid
        };
        rx.recv_timeout(WAIT).unwrap();
        let ctl = ProcessController::new(Arc::clone(&s.comm));
        assert!(ctl.kill(&pid, "test").unwrap());
        let record = s.sched.wait_terminal(&pid, WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "killed");
        assert_eq!(record.get_str("reason").unwrap(), "test");
        // Killed (non-finished) terminals keep their checkpoint for
        // post-mortem.
        assert!(s.store.load(&pid).unwrap().is_some());
        s.sched.shutdown();
    }

    #[test]
    fn pause_and_play_rpc() {
        let s = stack(2, 0);
        let (tx, rx) = std::sync::mpsc::channel();
        let pid = unique_id("proc");
        s.comm
            .add_broadcast_subscriber(
                BroadcastFilter::all().subject(&format!("state_changed.{pid}.waiting")),
                Box::new(move |_| {
                    tx.send(()).ok();
                }),
            )
            .unwrap();
        let t0 = Instant::now();
        s.sched
            .launch_with_pid(&pid, "napper", Value::map([("ms", Value::I64(30))]))
            .unwrap();
        rx.recv_timeout(WAIT).unwrap();
        let ctl = ProcessController::new(Arc::clone(&s.comm));
        assert!(ctl.pause(&pid).unwrap());
        // Give the pause time to settle, then verify the process holds
        // even though its 30 ms timer has long expired.
        std::thread::sleep(Duration::from_millis(150));
        let status = ctl.status(&pid).unwrap();
        assert_eq!(status.get_str("state").unwrap(), "paused");
        assert!(s.sched.wait_terminal(&pid, Duration::from_millis(50)).is_err());
        assert!(ctl.play(&pid).unwrap());
        let record = s.sched.wait_terminal(&pid, WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert!(t0.elapsed() >= Duration::from_millis(150), "pause must stretch the run");
        s.sched.shutdown();
    }

    #[test]
    fn panicking_step_excepts_cleanly() {
        let s = stack(1, 0);
        let pid = s.sched.launch("bomb", Value::map([])).unwrap();
        let record = s.sched.wait_terminal(&pid, WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "excepted");
        assert!(record.get_str("reason").unwrap().contains("kaboom"));
        // Terminal checkpoint retained; scheduler (and its single worker)
        // still alive for the next process.
        assert!(s.store.load(&pid).unwrap().is_some());
        let pid2 = s
            .sched
            .launch("adder", Value::map([("a", Value::I64(1)), ("b", Value::I64(2))]))
            .unwrap();
        let record2 = s.sched.wait_terminal(&pid2, WAIT).unwrap();
        assert_eq!(record2.get_str("state").unwrap(), "finished");
        s.sched.shutdown();
    }

    #[test]
    fn control_broadcast_kills_all_processes() {
        let s = stack(2, 0);
        let (tx, rx) = std::sync::mpsc::channel();
        s.comm
            .add_broadcast_subscriber(
                BroadcastFilter::all().subject("state_changed.*.waiting"),
                Box::new(move |_| {
                    tx.send(()).ok();
                }),
            )
            .unwrap();
        let pids: Vec<String> = (0..3)
            .map(|_| {
                s.sched
                    .launch("napper", Value::map([("ms", Value::I64(60_000))]))
                    .unwrap()
            })
            .collect();
        for _ in 0..3 {
            rx.recv_timeout(WAIT).unwrap();
        }
        let ctl = ProcessController::new(Arc::clone(&s.comm));
        ctl.broadcast_intent("kill").unwrap();
        for pid in &pids {
            let record = s.sched.wait_terminal(pid, WAIT).unwrap();
            assert_eq!(record.get_str("state").unwrap(), "killed");
            assert_eq!(record.get_str("reason").unwrap(), "killed by control broadcast");
        }
        s.sched.shutdown();
    }

    #[test]
    fn rpc_endpoint_removed_after_termination() {
        let s = stack(1, 0);
        let pid = s
            .sched
            .launch("adder", Value::map([("a", Value::I64(1)), ("b", Value::I64(1))]))
            .unwrap();
        s.sched.wait_terminal(&pid, WAIT).unwrap();
        let ctl = ProcessController::new(Arc::clone(&s.comm));
        assert!(ctl.status(&pid).is_err(), "terminal process must not be RPC-addressable");
        s.sched.shutdown();
    }

    /// The tentpole's park/resume cycle: with a tiny residency budget,
    /// waiting processes are evicted to their checkpoints and re-enter
    /// through the task queue when their wait resolves.
    #[test]
    fn parked_processes_resume_through_task_queue() {
        let s = stack(2, 2);
        self_consume(&s);
        let pids: Vec<String> = (0..6)
            .map(|_| {
                s.sched
                    .launch("napper", Value::map([("ms", Value::I64(80))]))
                    .unwrap()
            })
            .collect();
        for pid in &pids {
            let record = s.sched.wait_terminal(pid, WAIT).unwrap();
            assert_eq!(record.get_str("state").unwrap(), "finished");
        }
        let stats = s.sched.stats();
        assert!(stats.parked_total >= 1, "residency cap must have parked some processes");
        assert!(stats.resumed_total >= 1, "parked processes must resume via the queue");
        assert_eq!(stats.resident, 0);
        assert_eq!(stats.parked, 0);
        s.sched.shutdown();
    }

    #[test]
    fn resume_stored_requeues_interrupted_processes() {
        let s = stack(2, 0);
        self_consume(&s);
        s.store
            .save(&Bundle {
                pid: "orphan".into(),
                process_type: "tally".into(),
                state: ProcessState::Running,
                step: 2,
                logic_state: Value::map([]),
                wait: None,
            })
            .unwrap();
        assert_eq!(s.sched.resume_stored().unwrap(), 1);
        let record = s.sched.wait_terminal("orphan", WAIT).unwrap();
        assert_eq!(record.get("outputs").unwrap().get_i64("resumed_at").unwrap(), 2);
        // Nothing left to resume.
        assert_eq!(s.sched.resume_stored().unwrap(), 0);
        s.sched.shutdown();
    }
}
