//! Process lifecycle state machine (plumpy's states, same names).
//!
//! ```text
//! Created ──play──▶ Running ◀─────play───── Paused
//!                   │  ▲ │ ▲                  ▲
//!                   │  │ │ └──wait done──┐    │
//!                   │  │ └—─wait────▶ Waiting─┴──pause
//!                   │  └──────────────────┘
//!                   ├──▶ Finished   (terminal)
//!                   ├──▶ Excepted   (terminal)
//!                   └──▶ Killed     (terminal)
//! ```

use crate::error::{Error, Result};

/// Lifecycle states.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProcessState {
    Created,
    Running,
    Waiting,
    Paused,
    Finished,
    Excepted,
    Killed,
}

/// Events that drive transitions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProcessEvent {
    Play,
    Pause,
    Wait,
    Resume,
    Finish,
    Except,
    Kill,
}

impl ProcessState {
    pub fn as_str(&self) -> &'static str {
        match self {
            ProcessState::Created => "created",
            ProcessState::Running => "running",
            ProcessState::Waiting => "waiting",
            ProcessState::Paused => "paused",
            ProcessState::Finished => "finished",
            ProcessState::Excepted => "excepted",
            ProcessState::Killed => "killed",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "created" => Ok(ProcessState::Created),
            "running" => Ok(ProcessState::Running),
            "waiting" => Ok(ProcessState::Waiting),
            "paused" => Ok(ProcessState::Paused),
            "finished" => Ok(ProcessState::Finished),
            "excepted" => Ok(ProcessState::Excepted),
            "killed" => Ok(ProcessState::Killed),
            other => Err(Error::Persistence(format!("unknown process state '{other}'"))),
        }
    }

    /// Terminal states never transition again.
    pub fn is_terminal(&self) -> bool {
        matches!(self, ProcessState::Finished | ProcessState::Excepted | ProcessState::Killed)
    }

    /// Apply an event; `Err(InvalidStateTransition)` when not allowed.
    pub fn apply(&self, event: ProcessEvent) -> Result<ProcessState> {
        use ProcessEvent as E;
        use ProcessState as S;
        let next = match (self, event) {
            (S::Created, E::Play) => S::Running,
            (S::Created, E::Kill) => S::Killed,
            (S::Running, E::Wait) => S::Waiting,
            (S::Running, E::Pause) => S::Paused,
            (S::Running, E::Finish) => S::Finished,
            (S::Running, E::Except) => S::Excepted,
            (S::Running, E::Kill) => S::Killed,
            (S::Waiting, E::Resume) => S::Running,
            (S::Waiting, E::Pause) => S::Paused,
            (S::Waiting, E::Except) => S::Excepted,
            (S::Waiting, E::Kill) => S::Killed,
            (S::Paused, E::Play) => S::Running,
            (S::Paused, E::Kill) => S::Killed,
            (S::Paused, E::Except) => S::Excepted,
            (from, ev) => {
                return Err(Error::InvalidStateTransition {
                    from: from.as_str().to_string(),
                    event: format!("{ev:?}"),
                })
            }
        };
        Ok(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{run_prop, Rng};
    use ProcessEvent as E;
    use ProcessState as S;

    #[test]
    fn happy_path() {
        let s = S::Created;
        let s = s.apply(E::Play).unwrap();
        assert_eq!(s, S::Running);
        let s = s.apply(E::Wait).unwrap();
        assert_eq!(s, S::Waiting);
        let s = s.apply(E::Resume).unwrap();
        let s = s.apply(E::Finish).unwrap();
        assert_eq!(s, S::Finished);
        assert!(s.is_terminal());
    }

    #[test]
    fn pause_resume_cycle() {
        let s = S::Running.apply(E::Pause).unwrap();
        assert_eq!(s, S::Paused);
        assert_eq!(s.apply(E::Play).unwrap(), S::Running);
    }

    #[test]
    fn terminal_states_are_sticky() {
        for terminal in [S::Finished, S::Excepted, S::Killed] {
            for ev in [E::Play, E::Pause, E::Wait, E::Resume, E::Finish, E::Except, E::Kill] {
                assert!(terminal.apply(ev).is_err(), "{terminal:?} must reject {ev:?}");
            }
        }
    }

    #[test]
    fn kill_allowed_from_all_live_states() {
        for live in [S::Created, S::Running, S::Waiting, S::Paused] {
            assert_eq!(live.apply(E::Kill).unwrap(), S::Killed);
        }
    }

    #[test]
    fn cannot_finish_from_paused() {
        assert!(S::Paused.apply(E::Finish).is_err());
        assert!(S::Created.apply(E::Finish).is_err());
    }

    #[test]
    fn roundtrip_names() {
        for s in [S::Created, S::Running, S::Waiting, S::Paused, S::Finished, S::Excepted, S::Killed]
        {
            assert_eq!(ProcessState::parse(s.as_str()).unwrap(), s);
        }
        assert!(ProcessState::parse("bogus").is_err());
    }

    #[test]
    fn prop_no_escape_from_terminal() {
        run_prop("terminal absorbing", |rng: &Rng| {
            let mut s = S::Created;
            let events =
                [E::Play, E::Pause, E::Wait, E::Resume, E::Finish, E::Except, E::Kill];
            let mut was_terminal = false;
            for _ in 0..rng.range(1, 50) {
                let ev = *rng.pick(&events);
                match s.apply(ev) {
                    Ok(next) => {
                        assert!(!was_terminal, "escaped terminal state");
                        s = next;
                    }
                    Err(_) => {}
                }
                was_terminal = s.is_terminal();
            }
        });
    }
}
