//! Process controller: the client side of §I.B — pause/play/kill/status
//! RPCs to live processes, individually or broadcast to all at once
//! (§I.C's first use-case).

use std::sync::Arc;
use std::time::Duration;

use crate::communicator::{Communicator, KiwiFuture};
use crate::error::Result;
use crate::wire::Value;
use crate::workflow::process_rpc_id;

/// Controls live processes through a communicator.
pub struct ProcessController {
    comm: Arc<dyn Communicator>,
    timeout: Duration,
}

impl ProcessController {
    pub fn new(comm: Arc<dyn Communicator>) -> Self {
        ProcessController { comm, timeout: Duration::from_secs(10) }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    fn intent(
        &self,
        pid: &str,
        intent: &str,
        extra: Option<(&str, Value)>,
    ) -> Result<KiwiFuture<Value>> {
        let mut fields = vec![("intent", Value::str(intent))];
        if let Some((k, v)) = extra {
            fields.push((k, v));
        }
        self.comm.rpc_send(&process_rpc_id(pid), Value::map(fields))
    }

    /// Pause one process; resolves `true` when accepted.
    pub fn pause(&self, pid: &str) -> Result<bool> {
        Ok(self.intent(pid, "pause", None)?.wait(self.timeout)?.as_bool()?)
    }

    /// Resume a paused process.
    pub fn play(&self, pid: &str) -> Result<bool> {
        Ok(self.intent(pid, "play", None)?.wait(self.timeout)?.as_bool()?)
    }

    /// Kill a process with a reason.
    pub fn kill(&self, pid: &str, reason: &str) -> Result<bool> {
        Ok(self
            .intent(pid, "kill", Some(("reason", Value::str(reason))))?
            .wait(self.timeout)?
            .as_bool()?)
    }

    /// Status snapshot `{pid, state, step}`.
    pub fn status(&self, pid: &str) -> Result<Value> {
        self.intent(pid, "status", None)?.wait(self.timeout)
    }

    /// Broadcast a control message to *all* live processes (paper §I.C:
    /// "sending pause, play or kill messages to all processes at once").
    /// Processes act on it via their own broadcast subscription — see
    /// [`control_subject`]. Fire-and-forget.
    pub fn broadcast_intent(&self, intent: &str) -> Result<()> {
        self.comm.broadcast_send(
            Value::map([("intent", Value::str(intent))]),
            None,
            Some(&control_subject(intent)),
        )
    }
}

/// Broadcast subject carrying a global control intent.
pub fn control_subject(intent: &str) -> String {
    format!("control.all.{intent}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communicator::LocalCommunicator;
    use crate::error::Error;

    #[test]
    fn controller_talks_to_rpc_endpoint() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        comm.add_rpc_subscriber(
            &process_rpc_id("px"),
            Box::new(|msg| {
                Ok(match msg.get_str("intent")? {
                    "pause" | "play" | "kill" => Value::Bool(true),
                    "status" => Value::map([("pid", Value::str("px"))]),
                    _ => Value::Bool(false),
                })
            }),
        )
        .unwrap();
        let ctl = ProcessController::new(Arc::clone(&comm));
        assert!(ctl.pause("px").unwrap());
        assert!(ctl.play("px").unwrap());
        assert!(ctl.kill("px", "because").unwrap());
        assert_eq!(ctl.status("px").unwrap().get_str("pid").unwrap(), "px");
    }

    #[test]
    fn unknown_pid_is_unroutable() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let ctl = ProcessController::new(comm);
        assert!(matches!(ctl.pause("ghost"), Err(Error::UnroutableMessage(_))));
    }

    #[test]
    fn broadcast_intent_reaches_subscribers() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let (tx, rx) = std::sync::mpsc::channel();
        comm.add_broadcast_subscriber(
            crate::communicator::BroadcastFilter::all().subject("control.all.*"),
            Box::new(move |m| tx.send(m.subject.unwrap()).unwrap()),
        )
        .unwrap();
        let ctl = ProcessController::new(Arc::clone(&comm));
        ctl.broadcast_intent("pause").unwrap();
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(1)).unwrap(),
            "control.all.pause"
        );
    }
}
