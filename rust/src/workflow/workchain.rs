//! WorkChains: declarative multi-step workflows (AiiDA's `WorkChain`).
//!
//! A [`WorkChain`] is a [`ProcessLogic`] assembled from named steps
//! operating on a shared, checkpointable context (`ChainCtx`). Steps can
//! launch child processes and park the chain until they all terminate —
//! the parent learns of completion through the child's broadcast, never a
//! direct reply (paper §I.C).
//!
//! ```ignore
//! let chain = WorkChainSpec::new("eos")
//!     .step("setup", |cc, _ctx| { cc.set("i", Value::I64(0)); Ok(ChainStep::Next) })
//!     .step("launch", |cc, ctx| {
//!         let pid = ctx.spawn("relax", cc.get("structure")?.clone())?;
//!         cc.push("children", Value::str(&pid));
//!         Ok(ChainStep::WaitChildren)
//!     })
//!     .step("collect", |cc, ctx| { ... Ok(ChainStep::Finish(outputs)) });
//! registry.register("eos", move || chain.instantiate());
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::wire::Value;
use crate::workflow::process::{ProcessLogic, StepContext, StepOutcome, WaitCondition};

/// What a chain step decides.
pub enum ChainStep {
    /// Next step in the outline.
    Next,
    /// Jump to a named step (loops).
    Goto(&'static str),
    /// Park until every child in `ctx.children()` not yet collected
    /// terminates, then continue with the next step.
    WaitChildren,
    /// Park for a fixed duration.
    Sleep(Duration),
    /// Terminal success.
    Finish(Value),
}

/// A step body: mutates the chain context, optionally spawns children.
pub type ChainStepFn =
    Arc<dyn Fn(&mut ChainCtx, &mut StepContext) -> Result<ChainStep> + Send + Sync>;

/// The chain's persistent key-value context (serialised into checkpoints).
#[derive(Clone, Debug, Default)]
pub struct ChainCtx {
    map: BTreeMap<String, Value>,
}

impl ChainCtx {
    /// Inputs the chain was launched with.
    pub fn inputs(&self) -> Value {
        self.map.get("inputs").cloned().unwrap_or(Value::Null)
    }

    pub fn get(&self, key: &str) -> Result<&Value> {
        self.map.get(key).ok_or_else(|| Error::Persistence(format!("no context key '{key}'")))
    }

    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn set(&mut self, key: &str, value: Value) {
        self.map.insert(key.to_string(), value);
    }

    /// Append to a list-valued key (creating it if needed).
    pub fn push(&mut self, key: &str, value: Value) {
        match self.map.get_mut(key) {
            Some(Value::List(v)) => v.push(value),
            _ => {
                self.map.insert(key.to_string(), Value::List(vec![value]));
            }
        }
    }

    /// Child pids recorded via [`ChainCtx::add_child`].
    pub fn children(&self) -> Vec<String> {
        match self.map.get("__children") {
            Some(Value::List(v)) => {
                v.iter().filter_map(|x| x.as_str().ok().map(String::from)).collect()
            }
            _ => Vec::new(),
        }
    }

    /// Record a spawned child for `WaitChildren` / result collection.
    pub fn add_child(&mut self, pid: &str) {
        self.push("__children", Value::str(pid));
    }

    /// Clear the recorded children (after collecting a generation).
    pub fn clear_children(&mut self) {
        self.map.remove("__children");
    }
}

/// Immutable description of a workchain (shared by every instance).
pub struct WorkChainSpec {
    name: String,
    steps: Vec<(String, ChainStepFn)>,
}

impl WorkChainSpec {
    pub fn new(name: &str) -> Self {
        WorkChainSpec { name: name.to_string(), steps: Vec::new() }
    }

    /// Append a named step.
    pub fn step<F>(mut self, name: &str, f: F) -> Self
    where
        F: Fn(&mut ChainCtx, &mut StepContext) -> Result<ChainStep> + Send + Sync + 'static,
    {
        self.steps.push((name.to_string(), Arc::new(f)));
        self
    }

    /// Finish building: an `Arc`'d spec whose `instantiate()` feeds a
    /// process registry.
    pub fn build(self) -> Arc<WorkChainSpec> {
        Arc::new(self)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    fn index_of(&self, step_name: &str) -> Result<u32> {
        self.steps
            .iter()
            .position(|(n, _)| n == step_name)
            .map(|i| i as u32)
            .ok_or_else(|| {
                Error::Config(format!("workchain '{}': no step '{step_name}'", self.name))
            })
    }
}

/// Instantiate a runnable chain from a spec (one per process instance).
pub fn instantiate(spec: &Arc<WorkChainSpec>) -> Box<dyn ProcessLogic> {
    Box::new(WorkChain { spec: Arc::clone(spec), ctx: ChainCtx::default() })
}

/// The ProcessLogic adapter driving a spec.
pub struct WorkChain {
    spec: Arc<WorkChainSpec>,
    ctx: ChainCtx,
}

impl ProcessLogic for WorkChain {
    fn step(&mut self, step: u32, pctx: &mut StepContext) -> Result<StepOutcome> {
        let Some((_, f)) = self.spec.steps.get(step as usize) else {
            // Ran off the end of the outline: implicit finish with the
            // whole context as outputs (minus internals).
            let mut out = self.ctx.map.clone();
            out.retain(|k, _| !k.starts_with("__"));
            return Ok(StepOutcome::Finish(Value::Map(out)));
        };
        match f(&mut self.ctx, pctx)? {
            ChainStep::Next => Ok(StepOutcome::Continue),
            ChainStep::Goto(name) => Ok(StepOutcome::Goto(self.spec.index_of(name)?)),
            ChainStep::WaitChildren => {
                let pending: Vec<String> = self
                    .ctx
                    .children()
                    .into_iter()
                    .filter(|pid| matches!(pctx.child_result(pid), Ok(None)))
                    .collect();
                if pending.is_empty() {
                    Ok(StepOutcome::Continue)
                } else {
                    Ok(StepOutcome::Wait(WaitCondition::ProcessesTerminated(pending)))
                }
            }
            ChainStep::Sleep(d) => Ok(StepOutcome::Wait(WaitCondition::Timer(d))),
            ChainStep::Finish(outputs) => Ok(StepOutcome::Finish(outputs)),
        }
    }

    fn save_state(&self) -> Value {
        Value::Map(self.ctx.map.clone())
    }

    fn load_state(&mut self, state: &Value) -> Result<()> {
        self.ctx.map = state.as_map()?.clone();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communicator::{Communicator, LocalCommunicator};
    use crate::workflow::checkpoint::{CheckpointStore, MemoryCheckpointStore};
    use crate::workflow::registry::ProcessRegistry;
    use crate::workflow::launcher::DEFAULT_TASK_QUEUE;
    use crate::workflow::scheduler::{Scheduler, SchedulerConfig};

    const WAIT: Duration = Duration::from_secs(10);

    fn scheduler(registry: &ProcessRegistry) -> (Arc<dyn Communicator>, Arc<Scheduler>) {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let sched = Arc::new(
            Scheduler::start(
                Arc::clone(&comm),
                store,
                registry.clone(),
                SchedulerConfig { workers: 2, max_resident: 0, ..SchedulerConfig::default() },
            )
            .unwrap(),
        );
        (comm, sched)
    }

    /// Run one chain to terminal on a fresh scheduler; returns the record.
    fn run_chain(registry: &ProcessRegistry, pid: &str, ptype: &str) -> Value {
        let (_comm, sched) = scheduler(registry);
        sched.launch_with_pid(pid, ptype, Value::Null).unwrap();
        let record = sched.wait_terminal(pid, WAIT).unwrap();
        sched.shutdown();
        record
    }

    #[test]
    fn linear_chain_runs_and_implicit_finish() {
        let registry = ProcessRegistry::new();
        let spec = WorkChainSpec::new("linear")
            .step("a", |cc, _| {
                cc.set("x", Value::I64(1));
                Ok(ChainStep::Next)
            })
            .step("b", |cc, _| {
                let x = cc.get("x")?.as_i64()?;
                cc.set("y", Value::I64(x + 1));
                Ok(ChainStep::Next)
            })
            .build();
        registry.register("linear", move || instantiate(&spec));
        let record = run_chain(&registry, "wc1", "linear");
        assert_eq!(record.get_str("state").unwrap(), "finished");
        let out = record.get("outputs").unwrap();
        assert_eq!(out.get_i64("y").unwrap(), 2);
        assert!(out.get_opt("__children").is_none());
    }

    #[test]
    fn goto_implements_loops() {
        let registry = ProcessRegistry::new();
        let spec = WorkChainSpec::new("looper")
            .step("init", |cc, _| {
                cc.set("i", Value::I64(0));
                Ok(ChainStep::Next)
            })
            .step("body", |cc, _| {
                let i = cc.get("i")?.as_i64()? + 1;
                cc.set("i", Value::I64(i));
                if i < 5 {
                    Ok(ChainStep::Goto("body"))
                } else {
                    Ok(ChainStep::Finish(Value::map([("i", Value::I64(i))])))
                }
            })
            .build();
        registry.register("looper", move || instantiate(&spec));
        let record = run_chain(&registry, "wc2", "looper");
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert_eq!(record.get("outputs").unwrap(), &Value::map([("i", Value::I64(5))]));
    }

    #[test]
    fn goto_unknown_step_excepts() {
        let registry = ProcessRegistry::new();
        let spec = WorkChainSpec::new("bad")
            .step("a", |_, _| Ok(ChainStep::Goto("nowhere")))
            .build();
        registry.register("bad", move || instantiate(&spec));
        let record = run_chain(&registry, "wc3", "bad");
        assert_eq!(record.get_str("state").unwrap(), "excepted");
    }

    #[test]
    fn parent_awaits_children_via_broadcast() {
        // Full decoupled parent/child: the scheduler consumes its own task
        // queue (exactly what a daemon does), so spawned children are
        // admitted through the bounded worker pool — no thread per task —
        // and the parent waits on their terminal broadcasts (paper §I.C).
        let registry = ProcessRegistry::new();

        // Child: squares its input.
        let child_spec = WorkChainSpec::new("square")
            .step("go", |cc, _| {
                let x = cc.inputs().get_i64("x")?;
                Ok(ChainStep::Finish(Value::map([("sq", Value::I64(x * x))])))
            })
            .build();
        registry.register("square", move || instantiate(&child_spec));

        // Parent: spawns two children, waits for both, sums.
        let parent_spec = WorkChainSpec::new("summer")
            .step("spawn", |cc, ctx| {
                for x in [3i64, 4] {
                    let pid = ctx.spawn("square", Value::map([("x", Value::I64(x))]))?;
                    cc.add_child(&pid);
                }
                Ok(ChainStep::WaitChildren)
            })
            .step("collect", |cc, ctx| {
                let mut total = 0;
                for pid in cc.children() {
                    total += ctx.child_outputs(&pid)?.get_i64("sq")?;
                }
                Ok(ChainStep::Finish(Value::map([("total", Value::I64(total))])))
            })
            .build();
        registry.register("summer", move || instantiate(&parent_spec));

        let (comm, sched) = scheduler(&registry);
        let s2 = Arc::clone(&sched);
        comm.task_queue(
            DEFAULT_TASK_QUEUE,
            0,
            Box::new(move |task, tctx| s2.admit_task(task, tctx)),
        )
        .unwrap();

        sched.launch_with_pid("parent", "summer", Value::Null).unwrap();
        let record = sched.wait_terminal("parent", WAIT).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert_eq!(record.get("outputs").unwrap().get_i64("total").unwrap(), 25);
        sched.shutdown();
    }

    #[test]
    fn chain_state_roundtrips_through_checkpoint() {
        let spec = WorkChainSpec::new("s").step("a", |_, _| Ok(ChainStep::Next)).build();
        let mut chain = WorkChain { spec, ctx: ChainCtx::default() };
        chain.ctx.set("k", Value::F32s(vec![1.0, 2.0]));
        chain.ctx.add_child("c1");
        let saved = chain.save_state();
        let spec2 = WorkChainSpec::new("s").step("a", |_, _| Ok(ChainStep::Next)).build();
        let mut restored = WorkChain { spec: spec2, ctx: ChainCtx::default() };
        restored.load_state(&saved).unwrap();
        assert_eq!(restored.ctx.get("k").unwrap(), &Value::F32s(vec![1.0, 2.0]));
        assert_eq!(restored.ctx.children(), vec!["c1"]);
    }
}
