//! Process type registry: maps a `process_type` string (what goes into
//! task messages and checkpoints) to a factory producing fresh
//! [`ProcessLogic`] instances — how a daemon on another machine
//! reconstructs a process it has never seen.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::workflow::process::ProcessLogic;

type Factory = Arc<dyn Fn() -> Box<dyn ProcessLogic> + Send + Sync>;

/// Thread-safe, clonable registry (clones share the table).
#[derive(Clone, Default)]
pub struct ProcessRegistry {
    factories: Arc<Mutex<HashMap<String, Factory>>>,
}

impl ProcessRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a process type. Re-registering a name replaces the factory
    /// (tests do this; production code registers once at startup).
    pub fn register<F>(&self, process_type: &str, factory: F)
    where
        F: Fn() -> Box<dyn ProcessLogic> + Send + Sync + 'static,
    {
        self.factories.lock().unwrap().insert(process_type.to_string(), Arc::new(factory));
    }

    /// Instantiate a fresh logic for `process_type`.
    pub fn create(&self, process_type: &str) -> Result<Box<dyn ProcessLogic>> {
        let factories = self.factories.lock().unwrap();
        let f = factories
            .get(process_type)
            .ok_or_else(|| Error::Config(format!("unknown process type '{process_type}'")))?;
        Ok(f())
    }

    pub fn known_types(&self) -> Vec<String> {
        let mut v: Vec<String> = self.factories.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::Value;
    use crate::workflow::process::{StepContext, StepOutcome};

    struct Nop;
    impl ProcessLogic for Nop {
        fn step(&mut self, _step: u32, _ctx: &mut StepContext) -> crate::error::Result<StepOutcome> {
            Ok(StepOutcome::Finish(Value::Null))
        }
        fn save_state(&self) -> Value {
            Value::Null
        }
        fn load_state(&mut self, _state: &Value) -> crate::error::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn register_and_create() {
        let reg = ProcessRegistry::new();
        reg.register("nop", || Box::new(Nop));
        assert!(reg.create("nop").is_ok());
        assert!(reg.create("other").is_err());
        assert_eq!(reg.known_types(), vec!["nop"]);
    }

    #[test]
    fn clones_share_registrations() {
        let reg = ProcessRegistry::new();
        let reg2 = reg.clone();
        reg.register("nop", || Box::new(Nop));
        assert!(reg2.create("nop").is_ok());
    }
}
