//! Checkpoints: persistable process bundles.
//!
//! A [`Bundle`] captures everything needed to reconstruct a process on any
//! machine: its pid, logic type (registry key), lifecycle state, current
//! step and the logic's own saved state. Stores are pluggable; the file
//! store writes one JSON file per process (human-inspectable, like AiiDA's
//! database checkpoints).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::wire::{json, Value};
use crate::workflow::state::ProcessState;

/// The wait a checkpointed process was parked on, persisted so a resume
/// re-enters the *same* wait instead of restarting it.
///
/// Timer waits persist an **absolute deadline** (epoch milliseconds, so it
/// is meaningful on any machine): a process that checkpointed 40 s into a
/// 60 s sleep resumes with ~20 s left, and one whose deadline already
/// passed while it was parked resumes immediately — elapsed time is never
/// lost across a daemon restart.
#[derive(Clone, Debug, PartialEq)]
pub enum PersistedWait {
    /// Child pids whose terminal records are still outstanding.
    Children(Vec<String>),
    /// Absolute wall-clock deadline in milliseconds since the UNIX epoch.
    TimerDeadlineMs(u64),
}

impl PersistedWait {
    fn to_value(&self) -> Value {
        match self {
            PersistedWait::Children(pids) => Value::map([
                ("kind", Value::str("children")),
                ("pids", Value::list(pids.iter().map(Value::str))),
            ]),
            PersistedWait::TimerDeadlineMs(ms) => Value::map([
                ("kind", Value::str("timer")),
                ("deadline_ms", Value::from(*ms)),
            ]),
        }
    }

    fn from_value(v: &Value) -> Result<Self> {
        match v.get_str("kind")? {
            "children" => Ok(PersistedWait::Children(
                v.get("pids")?
                    .as_list()?
                    .iter()
                    .map(|p| p.as_str().map(String::from))
                    .collect::<Result<Vec<_>>>()?,
            )),
            "timer" => Ok(PersistedWait::TimerDeadlineMs(v.get_u64("deadline_ms")?)),
            other => Err(Error::Persistence(format!("unknown wait kind '{other}'"))),
        }
    }
}

/// Current wall-clock time in milliseconds since the UNIX epoch (the unit
/// [`PersistedWait::TimerDeadlineMs`] is expressed in).
pub fn epoch_ms_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// A serialised process.
#[derive(Clone, Debug, PartialEq)]
pub struct Bundle {
    pub pid: String,
    /// Registry key used to reconstruct the logic.
    pub process_type: String,
    pub state: ProcessState,
    /// Next step index to execute.
    pub step: u32,
    /// The logic's own state (inputs, intermediate context, ...).
    pub logic_state: Value,
    /// The wait the process was parked on when checkpointed (None for a
    /// process checkpointed between steps). Absent in pre-PersistedWait
    /// checkpoints, which load as `None`.
    pub wait: Option<PersistedWait>,
}

impl Bundle {
    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("pid", Value::str(&self.pid)),
            ("process_type", Value::str(&self.process_type)),
            ("state", Value::str(self.state.as_str())),
            ("step", Value::from(self.step as u64)),
            ("logic_state", self.logic_state.clone()),
        ];
        if let Some(wait) = &self.wait {
            fields.push(("wait", wait.to_value()));
        }
        Value::map(fields)
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(Bundle {
            pid: v.get_str("pid")?.to_string(),
            process_type: v.get_str("process_type")?.to_string(),
            state: ProcessState::parse(v.get_str("state")?)?,
            step: v.get_u64("step")? as u32,
            logic_state: v.get("logic_state")?.clone(),
            wait: match v.get_opt("wait") {
                Some(w) if !w.is_null() => Some(PersistedWait::from_value(w)?),
                _ => None,
            },
        })
    }
}

/// Where checkpoints live.
///
/// Besides live-process bundles, the store keeps **terminal output
/// records** (`save_outputs`). A finishing process persists its outputs
/// *before* broadcasting its terminal state, so a parent that was
/// checkpointed (and deaf) while the child finished finds the result here
/// on resume — the same role AiiDA's database plays, with broadcasts as
/// pure wake-ups.
pub trait CheckpointStore: Send + Sync {
    fn save(&self, bundle: &Bundle) -> Result<()>;
    fn load(&self, pid: &str) -> Result<Option<Bundle>>;
    fn delete(&self, pid: &str) -> Result<()>;
    /// Pids with a stored checkpoint (recovery scans).
    fn list(&self) -> Result<Vec<String>>;
    /// Persist a terminal record: `{state, outputs}`.
    fn save_outputs(&self, pid: &str, record: &Value) -> Result<()>;
    /// Terminal record, if the process already terminated.
    fn load_outputs(&self, pid: &str) -> Result<Option<Value>>;
}

/// In-memory store (tests, benches).
#[derive(Default)]
pub struct MemoryCheckpointStore {
    map: Mutex<BTreeMap<String, Bundle>>,
    outputs: Mutex<BTreeMap<String, Value>>,
}

impl MemoryCheckpointStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl CheckpointStore for MemoryCheckpointStore {
    fn save(&self, bundle: &Bundle) -> Result<()> {
        self.map.lock().unwrap().insert(bundle.pid.clone(), bundle.clone());
        Ok(())
    }

    fn load(&self, pid: &str) -> Result<Option<Bundle>> {
        Ok(self.map.lock().unwrap().get(pid).cloned())
    }

    fn delete(&self, pid: &str) -> Result<()> {
        self.map.lock().unwrap().remove(pid);
        Ok(())
    }

    fn list(&self) -> Result<Vec<String>> {
        Ok(self.map.lock().unwrap().keys().cloned().collect())
    }

    fn save_outputs(&self, pid: &str, record: &Value) -> Result<()> {
        self.outputs.lock().unwrap().insert(pid.to_string(), record.clone());
        Ok(())
    }

    fn load_outputs(&self, pid: &str) -> Result<Option<Value>> {
        Ok(self.outputs.lock().unwrap().get(pid).cloned())
    }
}

/// One JSON file per process under a directory. Writes are atomic
/// (temp + rename) so a crash mid-save never corrupts a checkpoint.
pub struct FileCheckpointStore {
    dir: PathBuf,
}

impl FileCheckpointStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(FileCheckpointStore { dir: dir.as_ref().to_path_buf() })
    }

    fn path(&self, pid: &str) -> PathBuf {
        // Sanitise: pids are generated by us but never trust path fragments.
        let safe: String = pid
            .chars()
            .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        self.dir.join(format!("{safe}.checkpoint.json"))
    }
}

impl CheckpointStore for FileCheckpointStore {
    fn save(&self, bundle: &Bundle) -> Result<()> {
        let path = self.path(&bundle.pid);
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, json::to_string_pretty(&bundle.to_value()))?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    fn load(&self, pid: &str) -> Result<Option<Bundle>> {
        let path = self.path(pid);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)?;
        let v = json::from_str(&text)
            .map_err(|e| Error::Persistence(format!("corrupt checkpoint {path:?}: {e}")))?;
        Ok(Some(Bundle::from_value(&v)?))
    }

    fn delete(&self, pid: &str) -> Result<()> {
        let path = self.path(pid);
        if path.exists() {
            std::fs::remove_file(path)?;
        }
        Ok(())
    }

    fn list(&self) -> Result<Vec<String>> {
        let mut pids = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let name = entry?.file_name().to_string_lossy().to_string();
            if let Some(pid) = name.strip_suffix(".checkpoint.json") {
                pids.push(pid.to_string());
            }
        }
        pids.sort();
        Ok(pids)
    }

    fn save_outputs(&self, pid: &str, record: &Value) -> Result<()> {
        let path = self.path(pid).with_extension("outputs.json");
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, json::to_string_pretty(record))?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    fn load_outputs(&self, pid: &str) -> Result<Option<Value>> {
        let path = self.path(pid).with_extension("outputs.json");
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)?;
        json::from_str(&text)
            .map(Some)
            .map_err(|e| Error::Persistence(format!("corrupt outputs {path:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle(pid: &str) -> Bundle {
        Bundle {
            pid: pid.into(),
            process_type: "eos".into(),
            state: ProcessState::Waiting,
            step: 3,
            logic_state: Value::map([
                ("inputs", Value::map([("volume", Value::F64(11.2))])),
                ("children", Value::list([Value::str("c1"), Value::str("c2")])),
            ]),
            wait: Some(PersistedWait::Children(vec!["c1".into(), "c2".into()])),
        }
    }

    #[test]
    fn bundle_value_roundtrip() {
        let b = bundle("p1");
        assert_eq!(Bundle::from_value(&b.to_value()).unwrap(), b);
    }

    #[test]
    fn bundle_roundtrips_timer_wait_and_none() {
        let mut b = bundle("p1");
        b.wait = Some(PersistedWait::TimerDeadlineMs(1_723_000_000_123));
        assert_eq!(Bundle::from_value(&b.to_value()).unwrap(), b);
        b.wait = None;
        assert_eq!(Bundle::from_value(&b.to_value()).unwrap(), b);
    }

    #[test]
    fn bundle_without_wait_field_loads_as_none() {
        // Pre-PersistedWait checkpoints have no "wait" key at all.
        let legacy = Value::map([
            ("pid", Value::str("old")),
            ("process_type", Value::str("eos")),
            ("state", Value::str("running")),
            ("step", Value::from(2u64)),
            ("logic_state", Value::Null),
        ]);
        let b = Bundle::from_value(&legacy).unwrap();
        assert_eq!(b.wait, None);
        assert_eq!(b.step, 2);
    }

    #[test]
    fn memory_store_crud() {
        let store = MemoryCheckpointStore::new();
        assert!(store.load("p1").unwrap().is_none());
        store.save(&bundle("p1")).unwrap();
        store.save(&bundle("p2")).unwrap();
        assert_eq!(store.load("p1").unwrap().unwrap().step, 3);
        assert_eq!(store.list().unwrap(), vec!["p1", "p2"]);
        store.delete("p1").unwrap();
        assert!(store.load("p1").unwrap().is_none());
    }

    #[test]
    fn file_store_roundtrip_and_atomicity() {
        let dir = std::env::temp_dir().join(format!("kiwi-ckpt-{}", std::process::id()));
        let store = FileCheckpointStore::open(&dir).unwrap();
        let b = bundle("proc-abc-1");
        store.save(&b).unwrap();
        // Overwrite is atomic and idempotent.
        store.save(&b).unwrap();
        assert_eq!(store.load("proc-abc-1").unwrap().unwrap(), b);
        assert_eq!(store.list().unwrap(), vec!["proc-abc-1"]);
        // Reopen sees the same data (fresh handle).
        let store2 = FileCheckpointStore::open(&dir).unwrap();
        assert_eq!(store2.load("proc-abc-1").unwrap().unwrap(), b);
        store2.delete("proc-abc-1").unwrap();
        assert!(store2.list().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_store_rejects_corrupt_checkpoint() {
        let dir = std::env::temp_dir().join(format!("kiwi-ckpt-bad-{}", std::process::id()));
        let store = FileCheckpointStore::open(&dir).unwrap();
        std::fs::write(dir.join("bad.checkpoint.json"), "{not json").unwrap();
        assert!(store.load("bad").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn outputs_records_separate_from_checkpoints() {
        let dir = std::env::temp_dir().join(format!("kiwi-ckpt-out-{}", std::process::id()));
        let store = FileCheckpointStore::open(&dir).unwrap();
        store.save(&bundle("p1")).unwrap();
        let record = Value::map([
            ("state", Value::str("finished")),
            ("outputs", Value::map([("energy", Value::F64(-1.5))])),
        ]);
        store.save_outputs("p1", &record).unwrap();
        assert_eq!(store.load_outputs("p1").unwrap().unwrap(), record);
        assert!(store.load_outputs("p2").unwrap().is_none());
        // The outputs file must not pollute the checkpoint list.
        assert_eq!(store.list().unwrap(), vec!["p1"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn memory_store_outputs() {
        let store = MemoryCheckpointStore::new();
        store.save_outputs("x", &Value::I64(5)).unwrap();
        assert_eq!(store.load_outputs("x").unwrap(), Some(Value::I64(5)));
    }

    #[test]
    fn path_sanitisation() {
        let dir = std::env::temp_dir().join(format!("kiwi-ckpt-san-{}", std::process::id()));
        let store = FileCheckpointStore::open(&dir).unwrap();
        let mut b = bundle("evil/../../pid");
        b.pid = "evil/../../pid".into();
        store.save(&b).unwrap();
        // The file must be inside the store dir.
        assert_eq!(store.list().unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
