//! Process launching over the task queue (§I.A).
//!
//! * [`RemoteLauncher`] — client side: `launch` / `continue_process` submit
//!   task messages; the task's future resolves with the process's terminal
//!   record when a daemon worker completes it.
//! * [`LaunchRequest`] — the task-message vocabulary both sides share.
//! * [`ProcessLauncher`] — worker side: a thin adapter feeding task
//!   messages into the event-driven [`Scheduler`].

use std::sync::Arc;

use crate::communicator::rmq::TaskContext;
use crate::communicator::{unique_id, Communicator, KiwiFuture};
use crate::error::{Error, Result};
use crate::wire::Value;
use crate::workflow::checkpoint::CheckpointStore;
use crate::workflow::registry::ProcessRegistry;
use crate::workflow::scheduler::{Scheduler, SchedulerConfig};

/// Default task queue name (AiiDA uses a single process queue too).
pub const DEFAULT_TASK_QUEUE: &str = "kiwi.tasks";

/// A parsed launch/continue task message.
#[derive(Clone, Debug, PartialEq)]
pub enum LaunchRequest {
    Launch { pid: String, process_type: String, inputs: Value },
    Continue { pid: String },
}

impl LaunchRequest {
    /// Parse a task-queue message (`{action: "launch"|"continue", ...}`).
    pub fn parse(task: &Value) -> Result<LaunchRequest> {
        match task.get_str("action")? {
            "launch" => Ok(LaunchRequest::Launch {
                pid: task.get_str("pid")?.to_string(),
                process_type: task.get_str("process_type")?.to_string(),
                inputs: task.get("inputs")?.clone(),
            }),
            "continue" => Ok(LaunchRequest::Continue { pid: task.get_str("pid")?.to_string() }),
            other => Err(Error::Broker(format!("unknown task action '{other}'"))),
        }
    }
}

/// Client-side launcher.
pub struct RemoteLauncher {
    comm: Arc<dyn Communicator>,
    queue: String,
}

impl RemoteLauncher {
    pub fn new(comm: Arc<dyn Communicator>) -> Self {
        Self::with_queue(comm, DEFAULT_TASK_QUEUE)
    }

    pub fn with_queue(comm: Arc<dyn Communicator>, queue: &str) -> Self {
        RemoteLauncher { comm, queue: queue.to_string() }
    }

    /// Launch a new process; returns `(pid, future of terminal record)`.
    pub fn launch(
        &self,
        process_type: &str,
        inputs: Value,
    ) -> Result<(String, KiwiFuture<Value>)> {
        let pid = unique_id("proc");
        let fut = self.comm.task_send(
            &self.queue,
            Value::map([
                ("action", Value::str("launch")),
                ("process_type", Value::str(process_type)),
                ("inputs", inputs),
                ("pid", Value::str(&pid)),
            ]),
        )?;
        Ok((pid, fut))
    }

    /// Ask a daemon to resume a checkpointed process.
    pub fn continue_process(&self, pid: &str) -> Result<KiwiFuture<Value>> {
        self.comm.task_send(
            &self.queue,
            Value::map([("action", Value::str("continue")), ("pid", Value::str(pid))]),
        )
    }
}

/// Worker-side interpreter of launch/continue tasks: hands them to the
/// scheduler's admission queue. Kept as a named type (rather than a bare
/// closure over [`Scheduler`]) so daemon wiring and tests have a stable
/// seam.
pub struct ProcessLauncher {
    sched: Arc<Scheduler>,
}

impl ProcessLauncher {
    /// Build a launcher around a fresh default-config scheduler.
    pub fn new(
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: ProcessRegistry,
    ) -> Result<Self> {
        let sched = Scheduler::start(comm, store, registry, SchedulerConfig::default())?;
        Ok(ProcessLauncher { sched: Arc::new(sched) })
    }

    /// Wrap an existing scheduler (the daemon path: the daemon owns the
    /// scheduler's lifecycle and config).
    pub fn with_scheduler(sched: Arc<Scheduler>) -> Self {
        ProcessLauncher { sched }
    }

    /// The scheduler executing this launcher's processes.
    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.sched
    }

    /// Enqueue one task message. Cheap — parsing and execution happen on
    /// the scheduler's worker pool, never on the delivery thread.
    pub fn handle_task(&self, task: Value, ctx: TaskContext) {
        self.sched.admit_task(task, ctx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communicator::LocalCommunicator;
    use crate::workflow::checkpoint::MemoryCheckpointStore;
    use crate::workflow::process::{ProcessLogic, StepContext, StepOutcome};
    use std::time::Duration;

    struct Echo {
        inputs: Value,
    }
    impl ProcessLogic for Echo {
        fn step(&mut self, _: u32, _: &mut StepContext) -> Result<StepOutcome> {
            Ok(StepOutcome::Finish(self.inputs.clone()))
        }
        fn save_state(&self) -> Value {
            self.inputs.clone()
        }
        fn load_state(&mut self, state: &Value) -> Result<()> {
            self.inputs = state.get_opt("inputs").cloned().unwrap_or(Value::Null);
            Ok(())
        }
    }

    #[test]
    fn launch_task_runs_process_and_replies() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let registry = ProcessRegistry::new();
        registry.register("echo", || Box::new(Echo { inputs: Value::Null }));
        let launcher = Arc::new(
            ProcessLauncher::new(Arc::clone(&comm), Arc::clone(&store), registry).unwrap(),
        );
        let l2 = Arc::clone(&launcher);
        comm.task_queue(
            DEFAULT_TASK_QUEUE,
            0,
            Box::new(move |task, ctx| l2.handle_task(task, ctx)),
        )
        .unwrap();

        let remote = RemoteLauncher::new(Arc::clone(&comm));
        let (pid, fut) = remote
            .launch("echo", Value::map([("x", Value::I64(9))]))
            .unwrap();
        let record = fut.wait(Duration::from_secs(5)).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert_eq!(record.get("outputs").unwrap().get_i64("x").unwrap(), 9);
        assert!(pid.starts_with("proc-"));
        launcher.scheduler().shutdown();
    }

    #[test]
    fn launch_requests_parse() {
        let launch = Value::map([
            ("action", Value::str("launch")),
            ("process_type", Value::str("echo")),
            ("inputs", Value::map([("x", Value::I64(1))])),
            ("pid", Value::str("p9")),
        ]);
        assert_eq!(
            LaunchRequest::parse(&launch).unwrap(),
            LaunchRequest::Launch {
                pid: "p9".into(),
                process_type: "echo".into(),
                inputs: Value::map([("x", Value::I64(1))]),
            }
        );
        let cont = Value::map([("action", Value::str("continue")), ("pid", Value::str("p9"))]);
        assert_eq!(
            LaunchRequest::parse(&cont).unwrap(),
            LaunchRequest::Continue { pid: "p9".into() }
        );
    }

    #[test]
    fn continue_without_checkpoint_errors() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let launcher =
            ProcessLauncher::new(Arc::clone(&comm), store, ProcessRegistry::new()).unwrap();
        assert!(launcher.scheduler().continue_local("ghost").is_err());
        launcher.scheduler().shutdown();
    }

    #[test]
    fn unknown_action_rejected() {
        let task = Value::map([("action", Value::str("explode"))]);
        assert!(LaunchRequest::parse(&task).is_err());
    }
}
