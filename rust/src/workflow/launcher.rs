//! Process launching over the task queue (§I.A).
//!
//! * [`RemoteLauncher`] — client side: `launch` / `continue_process` submit
//!   task messages; the task's future resolves with the process's terminal
//!   record when a daemon worker completes it.
//! * [`ProcessLauncher`] — worker side: interprets those task messages,
//!   builds a [`Runner`] (fresh or from checkpoint) and runs it.

use std::sync::Arc;

use crate::communicator::rmq::TaskContext;
use crate::communicator::{unique_id, Communicator, KiwiFuture};
use crate::error::{Error, Result};
use crate::wire::Value;
use crate::workflow::checkpoint::CheckpointStore;
use crate::workflow::process::Runner;
use crate::workflow::registry::ProcessRegistry;

/// Default task queue name (AiiDA uses a single process queue too).
pub const DEFAULT_TASK_QUEUE: &str = "kiwi.tasks";

/// Client-side launcher.
pub struct RemoteLauncher {
    comm: Arc<dyn Communicator>,
    queue: String,
}

impl RemoteLauncher {
    pub fn new(comm: Arc<dyn Communicator>) -> Self {
        Self::with_queue(comm, DEFAULT_TASK_QUEUE)
    }

    pub fn with_queue(comm: Arc<dyn Communicator>, queue: &str) -> Self {
        RemoteLauncher { comm, queue: queue.to_string() }
    }

    /// Launch a new process; returns `(pid, future of terminal record)`.
    pub fn launch(
        &self,
        process_type: &str,
        inputs: Value,
    ) -> Result<(String, KiwiFuture<Value>)> {
        let pid = unique_id("proc");
        let fut = self.comm.task_send(
            &self.queue,
            Value::map([
                ("action", Value::str("launch")),
                ("process_type", Value::str(process_type)),
                ("inputs", inputs),
                ("pid", Value::str(&pid)),
            ]),
        )?;
        Ok((pid, fut))
    }

    /// Ask a daemon to resume a checkpointed process.
    pub fn continue_process(&self, pid: &str) -> Result<KiwiFuture<Value>> {
        self.comm.task_send(
            &self.queue,
            Value::map([("action", Value::str("continue")), ("pid", Value::str(pid))]),
        )
    }
}

/// Worker-side interpreter of launch/continue tasks.
pub struct ProcessLauncher {
    comm: Arc<dyn Communicator>,
    store: Arc<dyn CheckpointStore>,
    registry: ProcessRegistry,
    queue: String,
}

impl ProcessLauncher {
    pub fn new(
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: ProcessRegistry,
    ) -> Self {
        Self::with_queue(comm, store, registry, DEFAULT_TASK_QUEUE)
    }

    pub fn with_queue(
        comm: Arc<dyn Communicator>,
        store: Arc<dyn CheckpointStore>,
        registry: ProcessRegistry,
        queue: &str,
    ) -> Self {
        ProcessLauncher { comm, store, registry, queue: queue.to_string() }
    }

    /// Build the runner a task message describes.
    pub fn runner_for(&self, task: &Value) -> Result<Runner> {
        match task.get_str("action")? {
            "launch" => Runner::launch(
                task.get_str("pid")?,
                task.get_str("process_type")?,
                task.get("inputs")?.clone(),
                Arc::clone(&self.comm),
                Arc::clone(&self.store),
                &self.registry,
                &self.queue,
            ),
            "continue" => {
                let pid = task.get_str("pid")?;
                let bundle = self
                    .store
                    .load(pid)?
                    .ok_or_else(|| Error::Persistence(format!("no checkpoint for '{pid}'")))?;
                Runner::from_bundle(
                    &bundle,
                    Arc::clone(&self.comm),
                    Arc::clone(&self.store),
                    &self.registry,
                    &self.queue,
                )
            }
            other => Err(Error::Broker(format!("unknown task action '{other}'"))),
        }
    }

    /// Execute one task message to completion and settle its context.
    /// This is what daemon workers run on their worker threads.
    pub fn handle_task(&self, task: Value, ctx: TaskContext) {
        match self.runner_for(&task) {
            Ok(runner) => {
                let result = runner.run().map(|outcome| outcome.to_record());
                ctx.complete(result);
            }
            Err(Error::Persistence(m)) => {
                // A `continue` task whose checkpoint this daemon cannot
                // see: checkpoint stores are per-daemon, so hand the task
                // back for a daemon that owns it. The task queue's
                // `max_delivery` cap turns a checkpoint *nobody* holds
                // into a dead-letter instead of an infinite redelivery
                // loop (the poison-pill path).
                log::warn!("launcher: cannot continue here ({m}); returning task to the queue");
                ctx.reject(true);
            }
            Err(e) => {
                log::warn!("launcher: task rejected: {e}");
                ctx.complete(Err(e));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::communicator::LocalCommunicator;
    use crate::workflow::checkpoint::MemoryCheckpointStore;
    use crate::workflow::process::{ProcessLogic, StepContext, StepOutcome};
    use std::time::Duration;

    struct Echo {
        inputs: Value,
    }
    impl ProcessLogic for Echo {
        fn step(&mut self, _: u32, _: &mut StepContext) -> Result<StepOutcome> {
            Ok(StepOutcome::Finish(self.inputs.clone()))
        }
        fn save_state(&self) -> Value {
            self.inputs.clone()
        }
        fn load_state(&mut self, state: &Value) -> Result<()> {
            self.inputs = state.get_opt("inputs").cloned().unwrap_or(Value::Null);
            Ok(())
        }
    }

    #[test]
    fn launch_task_runs_process_and_replies() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let registry = ProcessRegistry::new();
        registry.register("echo", || Box::new(Echo { inputs: Value::Null }));
        let launcher = Arc::new(ProcessLauncher::new(
            Arc::clone(&comm),
            Arc::clone(&store),
            registry,
        ));
        let l2 = Arc::clone(&launcher);
        comm.task_queue(
            DEFAULT_TASK_QUEUE,
            0,
            Box::new(move |task, ctx| l2.handle_task(task, ctx)),
        )
        .unwrap();

        let remote = RemoteLauncher::new(Arc::clone(&comm));
        let (pid, fut) = remote
            .launch("echo", Value::map([("x", Value::I64(9))]))
            .unwrap();
        let record = fut.wait(Duration::from_secs(5)).unwrap();
        assert_eq!(record.get_str("state").unwrap(), "finished");
        assert_eq!(record.get("outputs").unwrap().get_i64("x").unwrap(), 9);
        assert!(pid.starts_with("proc-"));
    }

    #[test]
    fn continue_task_without_checkpoint_errors() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let launcher =
            ProcessLauncher::new(Arc::clone(&comm), store, ProcessRegistry::new());
        let task = Value::map([("action", Value::str("continue")), ("pid", Value::str("ghost"))]);
        assert!(launcher.runner_for(&task).is_err());
    }

    #[test]
    fn unknown_action_rejected() {
        let comm: Arc<dyn Communicator> = Arc::new(LocalCommunicator::new());
        let store: Arc<dyn CheckpointStore> = Arc::new(MemoryCheckpointStore::new());
        let launcher =
            ProcessLauncher::new(Arc::clone(&comm), store, ProcessRegistry::new());
        let task = Value::map([("action", Value::str("explode"))]);
        assert!(launcher.runner_for(&task).is_err());
    }
}
