//! Minimal property-based testing harness.
//!
//! `proptest` is unavailable in this offline environment, so this module
//! provides the subset we need: a deterministic, seedable PRNG
//! (xorshift64*), generator combinators for the crate's core data types,
//! and a `run_prop` driver that runs a property over many random cases and
//! reports the failing seed so a failure is reproducible with
//! `KIWI_PROP_SEED=<seed> cargo test`.

use std::cell::Cell;

/// Deterministic xorshift64* PRNG. Not cryptographic; used only for tests
/// and synthetic workload generation.
#[derive(Clone, Debug)]
pub struct Rng {
    state: Cell<u64>,
}

impl Rng {
    /// Create a PRNG from a non-zero seed (zero is mapped to a fixed odd
    /// constant — xorshift has a fixed point at zero).
    pub fn new(seed: u64) -> Self {
        let s = if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed };
        Rng { state: Cell::new(s) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&self) -> u64 {
        let mut x = self.state.get();
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state.set(x);
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform u64 in `[0, bound)`; `bound` must be non-zero.
    pub fn below(&self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift bounded rejection-free map (slight modulo bias is
        // irrelevant for tests/workloads).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)` (half-open); `hi > lo`.
    pub fn range(&self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform i64 over the full range.
    pub fn i64(&self) -> i64 {
        self.next_u64() as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&self) -> f32 {
        self.f64() as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random ASCII alphanumeric string of length in `[0, max_len]`.
    pub fn string(&self, max_len: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_.-";
        let len = self.range(0, max_len + 1);
        (0..len).map(|_| CHARS[self.range(0, CHARS.len())] as char).collect()
    }

    /// Random bytes of length in `[0, max_len]`.
    pub fn bytes(&self, max_len: usize) -> Vec<u8> {
        let len = self.range(0, max_len + 1);
        (0..len).map(|_| self.below(256) as u8).collect()
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.range(0, i + 1));
        }
    }
}

/// Generators for the crate's core data types, shared by the in-tree
/// property tests and the protocol fuzz suite (`tests/protocol_fuzz.rs`).
pub mod generators {
    use super::Rng;
    use crate::wire::Value;
    use std::collections::BTreeMap;

    /// A random [`Value`] tree of bounded depth. At depth 0 only leaves
    /// are produced, so generation always terminates; sizes are kept
    /// small — fuzz throughput beats individual-case bulk.
    pub fn value(rng: &Rng, depth: usize) -> Value {
        let scalar_only = depth == 0;
        match rng.below(if scalar_only { 7 } else { 9 }) {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::I64(rng.i64()),
            3 => {
                // Finite floats only: NaN breaks `decode(encode(x)) == x`
                // for reasons that are the float's fault, not the codec's.
                Value::F64((rng.f64() - 0.5) * 1e12)
            }
            4 => Value::Str(rng.string(24)),
            5 => Value::Bytes(rng.bytes(48)),
            6 => Value::F32s((0..rng.range(0, 9)).map(|_| rng.f32()).collect()),
            7 => Value::List((0..rng.range(0, 5)).map(|_| value(rng, depth - 1)).collect()),
            _ => {
                let mut m = BTreeMap::new();
                for _ in 0..rng.range(0, 5) {
                    m.insert(rng.string(12), value(rng, depth - 1));
                }
                Value::Map(m)
            }
        }
    }
}

/// Number of cases `run_prop` executes per property (overridable with
/// `KIWI_PROP_CASES`).
pub fn default_cases() -> u32 {
    std::env::var("KIWI_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(128)
}

/// Run `prop` over `cases` random inputs. Each case gets an `Rng` seeded
/// from a base seed (env `KIWI_PROP_SEED` or a fixed default) plus the case
/// index; on panic the failing seed is printed so the case can be replayed.
pub fn run_prop<F: Fn(&Rng)>(name: &str, prop: F) {
    let base: u64 = std::env::var("KIWI_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_0F_1234_ABCD);
    let cases = default_cases();
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        let rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {i} (KIWI_PROP_SEED={base}, case seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let a = Rng::new(42);
        let b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let rng = Rng::new(7);
        for _ in 0..10_000 {
            assert!(rng.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let rng = Rng::new(9);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let rng = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn run_prop_runs_all_cases() {
        let count = std::cell::Cell::new(0u32);
        run_prop("counter", |_| {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), default_cases());
    }
}
