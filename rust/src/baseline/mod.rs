//! The status-quo baseline the paper argues against (§I: "home-made queue
//! data structures, race condition susceptible locks and polling based
//! solutions being commonplace"): a file-system polling task queue, built
//! the way academic codes actually build them. Benchmarked head-to-head
//! against the event-based broker in `benches/baseline_polling.rs` (E6).

pub mod polling;

pub use polling::{PollingQueue, PollingWorker};
