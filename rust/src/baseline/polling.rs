//! A directory-based polling task queue.
//!
//! Protocol (faithful to countless home-made lab pipelines):
//!
//! * submit: write `<id>.task` into the spool directory.
//! * claim: workers scan the directory every `poll_interval` and claim a
//!   task by atomically renaming `<id>.task` → `<id>.claimed` (rename is
//!   the "lock"; on POSIX only one claimant wins).
//! * complete: write `<id>.result`, remove `<id>.claimed`.
//! * collect: the submitter polls for `<id>.result`.
//!
//! Faults: a worker that dies after claiming leaves a `.claimed` file that
//! nobody retries until a *janitor* pass re-queues stale claims — the
//! polling analog of requeue-on-death, with detection latency set by the
//! janitor period rather than heartbeats.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime};

use crate::error::{Error, Result};
use crate::wire::{json, Value};

static SUBMIT_SEQ: AtomicU64 = AtomicU64::new(1);

/// Handle to a spool directory.
#[derive(Clone)]
pub struct PollingQueue {
    dir: PathBuf,
}

/// A claimed task: process it, then call [`PollingQueue::complete`].
pub struct ClaimedTask {
    pub id: String,
    pub task: Value,
    claimed_path: PathBuf,
}

impl PollingQueue {
    /// Open (creating) a spool directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(PollingQueue { dir: dir.as_ref().to_path_buf() })
    }

    /// Submit a task; returns its id.
    pub fn submit(&self, task: &Value) -> Result<String> {
        let id = format!(
            "{}-{}",
            std::process::id(),
            SUBMIT_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let tmp = self.dir.join(format!("{id}.tmp"));
        std::fs::write(&tmp, json::to_string(task))?;
        std::fs::rename(&tmp, self.dir.join(format!("{id}.task")))?;
        Ok(id)
    }

    /// Scan once for a task and try to claim it. `Ok(None)` = spool empty
    /// (the caller sleeps `poll_interval` — that sleep IS the baseline's
    /// latency floor).
    pub fn try_claim(&self) -> Result<Option<ClaimedTask>> {
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            let Some(id) = name.strip_suffix(".task") else { continue };
            let claimed = self.dir.join(format!("{id}.claimed"));
            // Atomic rename: exactly one scanning worker wins this task.
            match std::fs::rename(&path, &claimed) {
                Ok(()) => {
                    let text = std::fs::read_to_string(&claimed)?;
                    let task = json::from_str(&text)?;
                    return Ok(Some(ClaimedTask {
                        id: id.to_string(),
                        task,
                        claimed_path: claimed,
                    }));
                }
                Err(_) => continue, // raced; someone else claimed it
            }
        }
        Ok(None)
    }

    /// Finish a claimed task with its result.
    pub fn complete(&self, claimed: ClaimedTask, result: &Value) -> Result<()> {
        let tmp = self.dir.join(format!("{}.rtmp", claimed.id));
        std::fs::write(&tmp, json::to_string(result))?;
        std::fs::rename(&tmp, self.dir.join(format!("{}.result", claimed.id)))?;
        std::fs::remove_file(&claimed.claimed_path).ok();
        Ok(())
    }

    /// Non-blocking result check.
    pub fn try_result(&self, id: &str) -> Result<Option<Value>> {
        let path = self.dir.join(format!("{id}.result"));
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)?;
        Ok(Some(json::from_str(&text)?))
    }

    /// Poll for a result (the submitter's half of the polling tax).
    pub fn wait_result(
        &self,
        id: &str,
        poll_interval: Duration,
        timeout: Duration,
    ) -> Result<Value> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(v) = self.try_result(id)? {
                return Ok(v);
            }
            if Instant::now() >= deadline {
                return Err(Error::Timeout(format!("polling result for '{id}'")));
            }
            std::thread::sleep(poll_interval);
        }
    }

    /// Janitor: re-queue `.claimed` files older than `stale_after`
    /// (crashed-worker recovery, polling style). Returns how many.
    pub fn requeue_stale(&self, stale_after: Duration) -> Result<usize> {
        let mut n = 0;
        let now = SystemTime::now();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|x| x.to_str()) else { continue };
            let Some(id) = name.strip_suffix(".claimed") else { continue };
            let age = entry_age(&path, now);
            if age >= stale_after
                && std::fs::rename(&path, self.dir.join(format!("{id}.task"))).is_ok()
            {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Tasks waiting in the spool (bench instrumentation).
    pub fn depth(&self) -> Result<usize> {
        let mut n = 0;
        for entry in std::fs::read_dir(&self.dir)? {
            if entry?.path().extension().map(|e| e == "task").unwrap_or(false) {
                n += 1;
            }
        }
        Ok(n)
    }
}

fn entry_age(path: &Path, now: SystemTime) -> Duration {
    std::fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|t| now.duration_since(t).ok())
        .unwrap_or_default()
}

/// A polling worker thread: scan → claim → handle → complete → sleep.
pub struct PollingWorker {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    /// Number of directory scans performed (the busy-poll overhead metric).
    pub scans: Arc<AtomicU64>,
}

impl PollingWorker {
    pub fn spawn(
        queue: PollingQueue,
        poll_interval: Duration,
        mut handler: impl FnMut(&Value) -> Value + Send + 'static,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let scans = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let scans2 = Arc::clone(&scans);
        let handle = std::thread::Builder::new()
            .name("kiwi-polling-worker".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    scans2.fetch_add(1, Ordering::Relaxed);
                    match queue.try_claim() {
                        Ok(Some(claimed)) => {
                            let result = handler(&claimed.task);
                            queue.complete(claimed, &result).ok();
                            // Hot streak: immediately re-scan while there
                            // is work (the best case for polling).
                        }
                        Ok(None) => std::thread::sleep(poll_interval),
                        Err(e) => {
                            log::warn!("polling worker: {e}");
                            std::thread::sleep(poll_interval);
                        }
                    }
                }
            })
            .expect("spawn polling worker");
        PollingWorker { stop, handle: Some(handle), scans }
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for PollingWorker {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_spool(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kiwi-spool-{tag}-{}", std::process::id()))
    }

    #[test]
    fn submit_claim_complete_roundtrip() {
        let dir = temp_spool("rt");
        let q = PollingQueue::open(&dir).unwrap();
        let id = q.submit(&Value::map([("x", Value::I64(5))])).unwrap();
        assert_eq!(q.depth().unwrap(), 1);
        let claimed = q.try_claim().unwrap().unwrap();
        assert_eq!(claimed.task.get_i64("x").unwrap(), 5);
        assert_eq!(q.depth().unwrap(), 0);
        assert!(q.try_result(&id).unwrap().is_none());
        q.complete(claimed, &Value::str("done")).unwrap();
        assert_eq!(q.try_result(&id).unwrap().unwrap(), Value::str("done"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_spool_claims_nothing() {
        let dir = temp_spool("empty");
        let q = PollingQueue::open(&dir).unwrap();
        assert!(q.try_claim().unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn each_task_claimed_exactly_once() {
        let dir = temp_spool("once");
        let q = PollingQueue::open(&dir).unwrap();
        for i in 0..20 {
            q.submit(&Value::I64(i)).unwrap();
        }
        // Two competing claimants drain the spool; no task twice.
        let mut seen = Vec::new();
        let (q1, q2) = (q.clone(), q.clone());
        loop {
            let a = q1.try_claim().unwrap();
            let b = q2.try_claim().unwrap();
            if a.is_none() && b.is_none() {
                break;
            }
            for c in [a, b].into_iter().flatten() {
                seen.push(c.task.as_i64().unwrap());
                q.complete(c, &Value::Null).unwrap();
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn worker_processes_and_result_waits() {
        let dir = temp_spool("worker");
        let q = PollingQueue::open(&dir).unwrap();
        let worker = PollingWorker::spawn(q.clone(), Duration::from_millis(2), |task| {
            Value::I64(task.as_i64().unwrap() * 10)
        });
        let id = q.submit(&Value::I64(7)).unwrap();
        let result = q
            .wait_result(&id, Duration::from_millis(2), Duration::from_secs(5))
            .unwrap();
        assert_eq!(result, Value::I64(70));
        worker.stop();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wait_result_times_out() {
        let dir = temp_spool("timeout");
        let q = PollingQueue::open(&dir).unwrap();
        let err = q
            .wait_result("nope", Duration::from_millis(1), Duration::from_millis(20))
            .unwrap_err();
        assert!(matches!(err, Error::Timeout(_)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn janitor_requeues_stale_claims() {
        let dir = temp_spool("janitor");
        let q = PollingQueue::open(&dir).unwrap();
        q.submit(&Value::str("orphan")).unwrap();
        let claimed = q.try_claim().unwrap().unwrap();
        // Simulate worker death: drop the claim without completing.
        let id = claimed.id.clone();
        drop(claimed);
        assert_eq!(q.depth().unwrap(), 0);
        // Stale immediately with a zero threshold.
        assert_eq!(q.requeue_stale(Duration::ZERO).unwrap(), 1);
        assert_eq!(q.depth().unwrap(), 1);
        let again = q.try_claim().unwrap().unwrap();
        assert_eq!(again.id, id);
        std::fs::remove_dir_all(&dir).ok();
    }
}
