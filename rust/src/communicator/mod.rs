//! The kiwiPy API: one `Communicator` exposing the paper's three message
//! types — **task queues**, **RPC** and **broadcasts** — with futures-based
//! results and a hidden communication thread.
//!
//! | kiwiPy (Python)            | here                                     |
//! |----------------------------|------------------------------------------|
//! | `comm.task_send(q, task)`  | [`Communicator::task_send`] → future     |
//! | `comm.add_task_subscriber` | [`Communicator::task_queue`]             |
//! | `comm.rpc_send(id, msg)`   | [`Communicator::rpc_send`] → future      |
//! | `comm.add_rpc_subscriber`  | [`Communicator::add_rpc_subscriber`]     |
//! | `comm.broadcast_send(...)` | [`Communicator::broadcast_send`]         |
//! | `comm.add_broadcast_subscriber` | [`Communicator::add_broadcast_subscriber`] |
//!
//! Two implementations: [`RmqCommunicator`] (over the broker, the real
//! deployment) and [`LocalCommunicator`] (pure in-process, the unit-test
//! substrate — kiwiPy ships the same pair).

pub mod filters;
pub mod futures;
pub mod local;
pub mod rmq;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::Result;
use crate::wire::Value;

pub use filters::BroadcastFilter;
pub use futures::{KiwiFuture, Promise};
pub use local::LocalCommunicator;
pub use rmq::{dead_letter_queue_name, RmqCommunicator, RmqConfig, TaskContext};

/// A broadcast message as seen by subscribers.
#[derive(Clone, Debug, PartialEq)]
pub struct BroadcastMessage {
    pub body: Value,
    /// Who sent it (free-form identity, e.g. a process id).
    pub sender: Option<String>,
    /// What it is about (dotted subject, e.g. `state_changed.123.finished`).
    pub subject: Option<String>,
    pub correlation_id: Option<String>,
}

impl BroadcastMessage {
    pub fn to_value(&self) -> Value {
        Value::map([
            ("body", self.body.clone()),
            ("sender", self.sender.clone().into()),
            ("subject", self.subject.clone().into()),
            ("correlation_id", self.correlation_id.clone().into()),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(BroadcastMessage {
            body: v.get("body")?.clone(),
            sender: v.get_opt("sender").map(|s| s.as_str().map(String::from)).transpose()?,
            subject: v.get_opt("subject").map(|s| s.as_str().map(String::from)).transpose()?,
            correlation_id: v
                .get_opt("correlation_id")
                .map(|s| s.as_str().map(String::from))
                .transpose()?,
        })
    }
}

/// Handler for incoming tasks. Receives the task body and a [`TaskContext`]
/// whose `complete`/`reject` may be called from any thread — this is how
/// the daemon offloads long-running work without stalling the
/// communication thread.
pub type TaskHandler = Box<dyn FnMut(Value, rmq::TaskContext) + Send>;

/// Handler for RPC requests: synchronous request → reply (kiwiPy's model —
/// RPCs are quick control messages like pause/play/kill).
pub type RpcHandler = Box<dyn FnMut(Value) -> Result<Value> + Send>;

/// Handler for broadcasts (no reply channel).
pub type BroadcastHandler = Box<dyn FnMut(BroadcastMessage) + Send>;

/// The kiwiPy communicator interface.
pub trait Communicator: Send + Sync {
    /// Submit a task to a (durable) task queue. The future resolves with
    /// the value the remote handler completes with.
    fn task_send(&self, queue: &str, task: Value) -> Result<KiwiFuture<Value>>;

    /// Subscribe to a task queue with a prefetch window. Returns a
    /// subscription id usable with `remove_task_subscriber`.
    fn task_queue(&self, queue: &str, prefetch: u32, handler: TaskHandler) -> Result<String>;

    /// Remove a task subscriber (in-flight tasks are requeued by the
    /// broker if unacked).
    fn remove_task_subscriber(&self, subscription_id: &str) -> Result<()>;

    /// Call the RPC subscriber registered under `recipient_id`.
    fn rpc_send(&self, recipient_id: &str, msg: Value) -> Result<KiwiFuture<Value>>;

    /// Register an RPC subscriber under a globally-addressable identifier.
    fn add_rpc_subscriber(&self, identifier: &str, handler: RpcHandler) -> Result<()>;

    /// Unregister an RPC subscriber.
    fn remove_rpc_subscriber(&self, identifier: &str) -> Result<()>;

    /// Fire-and-forget broadcast to every subscriber.
    fn broadcast_send(
        &self,
        body: Value,
        sender: Option<&str>,
        subject: Option<&str>,
    ) -> Result<()>;

    /// Subscribe to broadcasts matching `filter`. Returns a subscription id.
    fn add_broadcast_subscriber(
        &self,
        filter: BroadcastFilter,
        handler: BroadcastHandler,
    ) -> Result<String>;

    /// Remove a broadcast subscriber.
    fn remove_broadcast_subscriber(&self, subscription_id: &str) -> Result<()>;
}

static UNIQUE: AtomicU64 = AtomicU64::new(1);

/// Process-unique identifier with a readable prefix (consumer tags,
/// correlation ids, reply queues).
pub fn unique_id(prefix: &str) -> String {
    let n = UNIQUE.fetch_add(1, Ordering::Relaxed);
    format!("{prefix}-{}-{n:x}", std::process::id())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_ids_are_unique() {
        let a = unique_id("x");
        let b = unique_id("x");
        assert_ne!(a, b);
        assert!(a.starts_with("x-"));
    }

    #[test]
    fn broadcast_message_roundtrip() {
        let m = BroadcastMessage {
            body: Value::map([("k", Value::I64(1))]),
            sender: Some("proc-7".into()),
            subject: Some("state_changed.7.finished".into()),
            correlation_id: None,
        };
        assert_eq!(BroadcastMessage::from_value(&m.to_value()).unwrap(), m);
    }

    #[test]
    fn broadcast_message_optionals_none() {
        let m = BroadcastMessage {
            body: Value::Null,
            sender: None,
            subject: None,
            correlation_id: None,
        };
        assert_eq!(BroadcastMessage::from_value(&m.to_value()).unwrap(), m);
    }
}
