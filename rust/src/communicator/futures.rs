//! Thread-backed futures (promise/future pairs).
//!
//! kiwiPy exposes `concurrent.futures.Future` results so users get familiar
//! blocking semantics without touching coroutines; this is the Rust
//! equivalent: a `Condvar`-backed future that any thread can wait on, with
//! optional done-callbacks that run on the completing thread.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};

enum State<T> {
    Pending(Vec<Box<dyn FnOnce(&Result<T>) + Send>>),
    Done(Result<T>),
    /// Result already consumed by `wait`.
    Taken,
}

struct Inner<T> {
    state: Mutex<State<T>>,
    cond: Condvar,
}

/// The write side: complete it exactly once.
pub struct Promise<T> {
    inner: Arc<Inner<T>>,
}

/// The read side: wait (with timeout) or poll.
pub struct KiwiFuture<T> {
    inner: Arc<Inner<T>>,
}

/// Create a connected promise/future pair.
pub fn promise<T>() -> (Promise<T>, KiwiFuture<T>) {
    let inner = Arc::new(Inner {
        state: Mutex::new(State::Pending(Vec::new())),
        cond: Condvar::new(),
    });
    (Promise { inner: Arc::clone(&inner) }, KiwiFuture { inner })
}

impl<T> Promise<T> {
    /// Complete with a success value. Returns false if already completed.
    pub fn set_result(&self, value: T) -> bool {
        self.complete(Ok(value))
    }

    /// Complete with an error. Returns false if already completed.
    pub fn set_error(&self, err: Error) -> bool {
        self.complete(Err(err))
    }

    fn complete(&self, result: Result<T>) -> bool {
        let mut state = self.inner.state.lock().unwrap();
        match &mut *state {
            State::Pending(callbacks) => {
                let callbacks = std::mem::take(callbacks);
                *state = State::Done(result);
                // Run callbacks with the lock *held state read-only*: we
                // re-borrow the stored result after the transition.
                if let State::Done(res) = &*state {
                    for cb in callbacks {
                        cb(res);
                    }
                }
                self.inner.cond.notify_all();
                true
            }
            _ => false,
        }
    }
}

impl<T> KiwiFuture<T> {
    /// True once a result (or error) is set.
    pub fn is_done(&self) -> bool {
        !matches!(*self.inner.state.lock().unwrap(), State::Pending(_))
    }

    /// Block until completed or `timeout` elapses; consumes the result.
    pub fn wait(self, timeout: Duration) -> Result<T> {
        let mut state = self.inner.state.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match &mut *state {
                State::Done(_) => {
                    let done = std::mem::replace(&mut *state, State::Taken);
                    let State::Done(res) = done else { unreachable!() };
                    return res;
                }
                State::Taken => return Err(Error::Closed("future already consumed".into())),
                State::Pending(_) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return Err(Error::Timeout("future wait".into()));
                    }
                    let (guard, _) =
                        self.inner.cond.wait_timeout(state, deadline - now).unwrap();
                    state = guard;
                }
            }
        }
    }

    /// Register a callback to run when the future completes (immediately if
    /// it already has). Runs on the completing thread — keep it short.
    pub fn on_done(&self, cb: impl FnOnce(&Result<T>) + Send + 'static) {
        let mut state = self.inner.state.lock().unwrap();
        match &mut *state {
            State::Pending(callbacks) => callbacks.push(Box::new(cb)),
            State::Done(res) => cb(res),
            State::Taken => {}
        }
    }
}

impl<T> Clone for KiwiFuture<T> {
    fn clone(&self) -> Self {
        KiwiFuture { inner: Arc::clone(&self.inner) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_then_wait() {
        let (p, f) = promise();
        p.set_result(42);
        assert_eq!(f.wait(Duration::from_millis(10)).unwrap(), 42);
    }

    #[test]
    fn wait_blocks_until_set_from_other_thread() {
        let (p, f) = promise();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            p.set_result("late".to_string());
        });
        assert_eq!(f.wait(Duration::from_secs(2)).unwrap(), "late");
        t.join().unwrap();
    }

    #[test]
    fn timeout_when_never_set() {
        let (_p, f) = promise::<i32>();
        assert!(matches!(f.wait(Duration::from_millis(20)), Err(Error::Timeout(_))));
    }

    #[test]
    fn error_propagates() {
        let (p, f) = promise::<i32>();
        p.set_error(Error::RemoteException("boom".into()));
        assert!(matches!(f.wait(Duration::from_millis(10)), Err(Error::RemoteException(_))));
    }

    #[test]
    fn double_complete_rejected() {
        let (p, f) = promise();
        assert!(p.set_result(1));
        assert!(!p.set_result(2));
        assert!(!p.set_error(Error::Timeout("x".into())));
        assert_eq!(f.wait(Duration::from_millis(10)).unwrap(), 1);
    }

    #[test]
    fn is_done_tracks_state() {
        let (p, f) = promise();
        assert!(!f.is_done());
        p.set_result(());
        assert!(f.is_done());
    }

    #[test]
    fn on_done_fires_on_completion() {
        let (p, f) = promise();
        let (tx, rx) = std::sync::mpsc::channel();
        f.on_done(move |r| {
            tx.send(r.as_ref().copied().unwrap()).unwrap();
        });
        p.set_result(7);
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 7);
    }

    #[test]
    fn on_done_fires_immediately_if_already_done() {
        let (p, f) = promise();
        p.set_result(3);
        let (tx, rx) = std::sync::mpsc::channel();
        f.on_done(move |r| {
            tx.send(r.as_ref().copied().unwrap()).unwrap();
        });
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 3);
    }
}
