//! Thread-backed futures (promise/future pairs).
//!
//! kiwiPy exposes `concurrent.futures.Future` results so users get familiar
//! blocking semantics without touching coroutines; this is the Rust
//! equivalent: a `Condvar`-backed future that any thread can wait on, with
//! optional done-callbacks that run on the completing thread.
//!
//! Done-callbacks run with *no lock held*: the state transitions to `Done`
//! first, then callbacks observe the result through a shared handle — so a
//! callback touching the same future (`is_done`, `on_done`, a clone's
//! `wait` from another thread) works instead of deadlocking on the
//! non-reentrant state mutex.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

enum State<T> {
    Pending(Vec<Box<dyn FnOnce(&Result<T>) + Send>>),
    /// Result decided. The completing thread holds its own `Arc` clone
    /// while callbacks run, so `wait` may briefly contend for sole
    /// ownership right after completion.
    Done(Arc<Result<T>>),
    /// Result already consumed by `wait`.
    Taken,
}

struct Inner<T> {
    state: Mutex<State<T>>,
    cond: Condvar,
}

/// The write side: complete it exactly once.
pub struct Promise<T> {
    inner: Arc<Inner<T>>,
}

/// The read side: wait (with timeout) or poll.
pub struct KiwiFuture<T> {
    inner: Arc<Inner<T>>,
}

/// Create a connected promise/future pair.
pub fn promise<T>() -> (Promise<T>, KiwiFuture<T>) {
    let inner = Arc::new(Inner {
        state: Mutex::new(State::Pending(Vec::new())),
        cond: Condvar::new(),
    });
    (Promise { inner: Arc::clone(&inner) }, KiwiFuture { inner })
}

impl<T> Promise<T> {
    /// Complete with a success value. Returns false if already completed.
    pub fn set_result(&self, value: T) -> bool {
        self.complete(Ok(value))
    }

    /// Complete with an error. Returns false if already completed.
    pub fn set_error(&self, err: Error) -> bool {
        self.complete(Err(err))
    }

    fn complete(&self, result: Result<T>) -> bool {
        let res = Arc::new(result);
        let callbacks = {
            let mut state = self.inner.state.lock().unwrap();
            match &mut *state {
                State::Pending(callbacks) => {
                    let callbacks = std::mem::take(callbacks);
                    *state = State::Done(Arc::clone(&res));
                    self.inner.cond.notify_all();
                    callbacks
                }
                _ => return false,
            }
        };
        // Lock released: a callback that re-enters this future sees `Done`.
        for cb in callbacks {
            cb(&res);
        }
        // Release our borrow of the result and wake any `wait` that raced
        // the callbacks (it needs sole ownership to move the result out).
        drop(res);
        self.inner.cond.notify_all();
        true
    }
}

impl<T> KiwiFuture<T> {
    /// True once a result (or error) is set.
    pub fn is_done(&self) -> bool {
        !matches!(*self.inner.state.lock().unwrap(), State::Pending(_))
    }

    /// Block until completed or `timeout` elapses; consumes the result.
    ///
    /// Note: calling `wait` from *inside* a done-callback of this same
    /// future times out instead of returning — the callback itself borrows
    /// the result it would be waiting to own.
    pub fn wait(self, timeout: Duration) -> Result<T> {
        let deadline = Instant::now() + timeout;
        let mut state = self.inner.state.lock().unwrap();
        loop {
            match &mut *state {
                State::Done(_) => {
                    let done = std::mem::replace(&mut *state, State::Taken);
                    let State::Done(arc) = done else { unreachable!() };
                    match Arc::try_unwrap(arc) {
                        Ok(res) => return res,
                        Err(arc) => {
                            // Done-callbacks are still running with a
                            // borrow of the result; put it back and wait
                            // for the completing thread to finish.
                            *state = State::Done(arc);
                            let now = Instant::now();
                            if now >= deadline {
                                return Err(Error::Timeout("future wait".into()));
                            }
                            let wait = (deadline - now).min(Duration::from_millis(5));
                            let (guard, _) = self.inner.cond.wait_timeout(state, wait).unwrap();
                            state = guard;
                        }
                    }
                }
                State::Taken => return Err(Error::Closed("future already consumed".into())),
                State::Pending(_) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(Error::Timeout("future wait".into()));
                    }
                    let (guard, _) =
                        self.inner.cond.wait_timeout(state, deadline - now).unwrap();
                    state = guard;
                }
            }
        }
    }

    /// Register a callback to run when the future completes (immediately if
    /// it already has). Runs on the completing thread — keep it short. The
    /// callback runs without the state lock, so it may freely touch this
    /// future again.
    pub fn on_done(&self, cb: impl FnOnce(&Result<T>) + Send + 'static) {
        let run_now = {
            let mut state = self.inner.state.lock().unwrap();
            match &mut *state {
                State::Pending(callbacks) => {
                    callbacks.push(Box::new(cb));
                    return;
                }
                State::Done(res) => Some(Arc::clone(res)),
                State::Taken => None,
            }
        };
        if let Some(res) = run_now {
            cb(&res);
        }
    }
}

impl<T> Clone for KiwiFuture<T> {
    fn clone(&self) -> Self {
        KiwiFuture { inner: Arc::clone(&self.inner) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_then_wait() {
        let (p, f) = promise();
        p.set_result(42);
        assert_eq!(f.wait(Duration::from_millis(10)).unwrap(), 42);
    }

    #[test]
    fn wait_blocks_until_set_from_other_thread() {
        let (p, f) = promise();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            p.set_result("late".to_string());
        });
        assert_eq!(f.wait(Duration::from_secs(2)).unwrap(), "late");
        t.join().unwrap();
    }

    #[test]
    fn timeout_when_never_set() {
        let (_p, f) = promise::<i32>();
        assert!(matches!(f.wait(Duration::from_millis(20)), Err(Error::Timeout(_))));
    }

    #[test]
    fn error_propagates() {
        let (p, f) = promise::<i32>();
        p.set_error(Error::RemoteException("boom".into()));
        assert!(matches!(f.wait(Duration::from_millis(10)), Err(Error::RemoteException(_))));
    }

    #[test]
    fn double_complete_rejected() {
        let (p, f) = promise();
        assert!(p.set_result(1));
        assert!(!p.set_result(2));
        assert!(!p.set_error(Error::Timeout("x".into())));
        assert_eq!(f.wait(Duration::from_millis(10)).unwrap(), 1);
    }

    #[test]
    fn is_done_tracks_state() {
        let (p, f) = promise();
        assert!(!f.is_done());
        p.set_result(());
        assert!(f.is_done());
    }

    #[test]
    fn on_done_fires_on_completion() {
        let (p, f) = promise();
        let (tx, rx) = std::sync::mpsc::channel();
        f.on_done(move |r| {
            tx.send(r.as_ref().copied().unwrap()).unwrap();
        });
        p.set_result(7);
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 7);
    }

    #[test]
    fn on_done_fires_immediately_if_already_done() {
        let (p, f) = promise();
        p.set_result(3);
        let (tx, rx) = std::sync::mpsc::channel();
        f.on_done(move |r| {
            tx.send(r.as_ref().copied().unwrap()).unwrap();
        });
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 3);
    }

    #[test]
    fn reentrant_callback_does_not_deadlock() {
        // Regression: callbacks used to run while `complete` held the
        // state mutex, so a callback touching the same future deadlocked.
        let (p, f) = promise();
        let f2 = f.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        f.on_done(move |r| {
            assert!(f2.is_done(), "state must be Done before callbacks run");
            let value = *r.as_ref().unwrap();
            let tx2 = tx.clone();
            // Late registration runs immediately — also reentrant.
            f2.on_done(move |r2| {
                tx2.send(*r2.as_ref().unwrap() + 100).unwrap();
            });
            tx.send(value).unwrap();
        });
        let completer = std::thread::spawn(move || p.set_result(5));
        let mut got = vec![
            rx.recv_timeout(Duration::from_secs(2)).expect("reentrant callback deadlocked"),
            rx.recv_timeout(Duration::from_secs(2)).expect("nested on_done deadlocked"),
        ];
        got.sort_unstable();
        assert_eq!(got, vec![5, 105]);
        completer.join().unwrap();
    }

    #[test]
    fn clone_can_wait_while_callbacks_run() {
        let (p, f) = promise();
        let waiter_f = f.clone();
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let gate2 = Arc::clone(&gate);
        f.on_done(move |_| {
            started_tx.send(()).unwrap();
            while !gate2.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        let waiter = std::thread::spawn(move || waiter_f.wait(Duration::from_secs(5)));
        let completer = std::thread::spawn(move || p.set_result(9));
        // Callback is running (completion decided); the waiter blocks
        // until the callback releases its borrow, then gets the value.
        started_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        gate.store(true, std::sync::atomic::Ordering::Relaxed);
        assert_eq!(waiter.join().unwrap().unwrap(), 9);
        completer.join().unwrap();
    }
}
