//! [`LocalCommunicator`]: a pure in-process communicator with the same
//! interface and semantics as the broker-backed one, minus the wire —
//! kiwiPy ships the identical pair (`LocalCommunicator` /
//! `RmqCommunicator`) so tests and single-process tools can run without a
//! broker. Also the zero-overhead baseline the benches compare against.
//!
//! Handlers run synchronously on the calling thread. Task queues buffer
//! when no subscriber is attached and round-robin across subscribers,
//! matching broker behaviour.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::communicator::filters::BroadcastFilter;
use crate::communicator::futures::{promise, KiwiFuture, Promise};
use crate::communicator::rmq::TaskContext;
use crate::communicator::{
    unique_id, BroadcastHandler, BroadcastMessage, Communicator, RpcHandler, TaskHandler,
};
use crate::error::{Error, Result};
use crate::wire::Value;

type SharedTaskHandler = Arc<Mutex<TaskHandler>>;
type SharedRpcHandler = Arc<Mutex<RpcHandler>>;
type SharedBroadcastHandler = Arc<Mutex<BroadcastHandler>>;

#[derive(Default)]
struct Inner {
    /// queue -> subscribers (sub_id, handler).
    task_subs: HashMap<String, Vec<(String, SharedTaskHandler)>>,
    /// queue -> buffered tasks awaiting a subscriber.
    pending_tasks: HashMap<String, VecDeque<(Value, Promise<Value>)>>,
    /// queue -> round-robin cursor.
    rr: HashMap<String, usize>,
    rpc: HashMap<String, SharedRpcHandler>,
    broadcast: Vec<(String, BroadcastFilter, SharedBroadcastHandler)>,
}

/// In-process communicator (no broker, no threads).
#[derive(Clone, Default)]
pub struct LocalCommunicator {
    inner: Arc<Mutex<Inner>>,
}

impl LocalCommunicator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pick the next task subscriber for `queue` (round-robin), if any.
    fn next_subscriber(&self, queue: &str) -> Option<SharedTaskHandler> {
        let mut inner = self.inner.lock().unwrap();
        let subs = inner.task_subs.get(queue)?;
        if subs.is_empty() {
            return None;
        }
        let n = subs.len();
        let cursor = inner.rr.entry(queue.to_string()).or_insert(0);
        let idx = *cursor % n;
        *cursor = (*cursor + 1) % n;
        Some(Arc::clone(&inner.task_subs[queue][idx].1))
    }
}

impl Communicator for LocalCommunicator {
    fn task_send(&self, queue: &str, task: Value) -> Result<KiwiFuture<Value>> {
        let (p, f) = promise();
        match self.next_subscriber(queue) {
            Some(handler) => {
                // Invoke outside the registry lock so handlers can re-enter
                // the communicator.
                let ctx = TaskContext::local(p);
                (handler.lock().unwrap())(task, ctx);
            }
            None => {
                self.inner
                    .lock()
                    .unwrap()
                    .pending_tasks
                    .entry(queue.to_string())
                    .or_default()
                    .push_back((task, p));
            }
        }
        Ok(f)
    }

    fn task_queue(&self, queue: &str, _prefetch: u32, handler: TaskHandler) -> Result<String> {
        let sub_id = unique_id("local-task");
        let shared: SharedTaskHandler = Arc::new(Mutex::new(handler));
        let backlog = {
            let mut inner = self.inner.lock().unwrap();
            inner
                .task_subs
                .entry(queue.to_string())
                .or_default()
                .push((sub_id.clone(), Arc::clone(&shared)));
            inner.pending_tasks.remove(queue).unwrap_or_default()
        };
        // Drain anything that was buffered while nobody listened.
        for (task, p) in backlog {
            (shared.lock().unwrap())(task, TaskContext::local(p));
        }
        Ok(sub_id)
    }

    fn remove_task_subscriber(&self, subscription_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        for subs in inner.task_subs.values_mut() {
            let before = subs.len();
            subs.retain(|(id, _)| id != subscription_id);
            if subs.len() != before {
                return Ok(());
            }
        }
        Err(Error::Broker(format!("no task subscription '{subscription_id}'")))
    }

    fn rpc_send(&self, recipient_id: &str, msg: Value) -> Result<KiwiFuture<Value>> {
        let handler = {
            let inner = self.inner.lock().unwrap();
            inner.rpc.get(recipient_id).cloned()
        };
        let Some(handler) = handler else {
            return Err(Error::UnroutableMessage(format!("no rpc subscriber '{recipient_id}'")));
        };
        let (p, f) = promise();
        match (handler.lock().unwrap())(msg) {
            Ok(v) => p.set_result(v),
            Err(e) => p.set_error(Error::RemoteException(e.to_string())),
        };
        Ok(f)
    }

    fn add_rpc_subscriber(&self, identifier: &str, handler: RpcHandler) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.rpc.contains_key(identifier) {
            return Err(Error::DuplicateSubscriber(identifier.to_string()));
        }
        inner.rpc.insert(identifier.to_string(), Arc::new(Mutex::new(handler)));
        Ok(())
    }

    fn remove_rpc_subscriber(&self, identifier: &str) -> Result<()> {
        self.inner
            .lock()
            .unwrap()
            .rpc
            .remove(identifier)
            .map(|_| ())
            .ok_or_else(|| Error::Broker(format!("no rpc subscriber '{identifier}'")))
    }

    fn broadcast_send(
        &self,
        body: Value,
        sender: Option<&str>,
        subject: Option<&str>,
    ) -> Result<()> {
        let msg = BroadcastMessage {
            body,
            sender: sender.map(String::from),
            subject: subject.map(String::from),
            correlation_id: None,
        };
        let matching: Vec<SharedBroadcastHandler> = {
            let inner = self.inner.lock().unwrap();
            inner
                .broadcast
                .iter()
                .filter(|(_, f, _)| f.matches(&msg))
                .map(|(_, _, h)| Arc::clone(h))
                .collect()
        };
        for h in matching {
            (h.lock().unwrap())(msg.clone());
        }
        Ok(())
    }

    fn add_broadcast_subscriber(
        &self,
        filter: BroadcastFilter,
        handler: BroadcastHandler,
    ) -> Result<String> {
        let sub_id = unique_id("local-bc");
        self.inner.lock().unwrap().broadcast.push((
            sub_id.clone(),
            filter,
            Arc::new(Mutex::new(handler)),
        ));
        Ok(sub_id)
    }

    fn remove_broadcast_subscriber(&self, subscription_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.broadcast.len();
        inner.broadcast.retain(|(id, _, _)| id != subscription_id);
        if inner.broadcast.len() == before {
            return Err(Error::Broker(format!("no broadcast subscription '{subscription_id}'")));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn task_roundtrip() {
        let comm = LocalCommunicator::new();
        comm.task_queue(
            "sq",
            1,
            Box::new(|t, ctx| {
                let x = t.as_i64().unwrap();
                ctx.complete(Ok(Value::I64(x + 1)));
            }),
        )
        .unwrap();
        let f = comm.task_send("sq", Value::I64(41)).unwrap();
        assert_eq!(f.wait(Duration::from_secs(1)).unwrap(), Value::I64(42));
    }

    #[test]
    fn tasks_buffer_until_subscriber_arrives() {
        let comm = LocalCommunicator::new();
        let f = comm.task_send("later", Value::I64(5)).unwrap();
        assert!(!f.is_done());
        comm.task_queue(
            "later",
            1,
            Box::new(|t, ctx| ctx.complete(Ok(t))),
        )
        .unwrap();
        assert_eq!(f.wait(Duration::from_secs(1)).unwrap(), Value::I64(5));
    }

    #[test]
    fn round_robin_across_subscribers() {
        let comm = LocalCommunicator::new();
        for name in ["a", "b"] {
            comm.task_queue(
                "q",
                1,
                Box::new(move |_t, ctx| ctx.complete(Ok(Value::str(name)))),
            )
            .unwrap();
        }
        let winners: Vec<String> = (0..4)
            .map(|_| {
                comm.task_send("q", Value::Null)
                    .unwrap()
                    .wait(Duration::from_secs(1))
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .to_string()
            })
            .collect();
        assert_eq!(winners, vec!["a", "b", "a", "b"]);
    }

    #[test]
    fn rpc_roundtrip_and_unroutable() {
        let comm = LocalCommunicator::new();
        comm.add_rpc_subscriber("id", Box::new(|v| Ok(v))).unwrap();
        assert_eq!(
            comm.rpc_send("id", Value::str("x")).unwrap().wait(Duration::from_secs(1)).unwrap(),
            Value::str("x")
        );
        assert!(matches!(comm.rpc_send("ghost", Value::Null), Err(Error::UnroutableMessage(_))));
    }

    #[test]
    fn broadcast_with_filters() {
        let comm = LocalCommunicator::new();
        let (tx, rx) = std::sync::mpsc::channel();
        comm.add_broadcast_subscriber(
            BroadcastFilter::all().subject("boom.*"),
            Box::new(move |m| tx.send(m.body).unwrap()),
        )
        .unwrap();
        comm.broadcast_send(Value::I64(1), None, Some("quiet.1")).unwrap();
        comm.broadcast_send(Value::I64(2), None, Some("boom.1")).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), Value::I64(2));
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn handlers_can_reenter_communicator() {
        // A task handler that broadcasts — must not deadlock.
        let comm = LocalCommunicator::new();
        let comm2 = comm.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        comm.add_broadcast_subscriber(
            BroadcastFilter::all(),
            Box::new(move |m| tx.send(m.body).unwrap()),
        )
        .unwrap();
        comm.task_queue(
            "chatty",
            1,
            Box::new(move |t, ctx| {
                comm2.broadcast_send(t.clone(), None, None).unwrap();
                ctx.complete(Ok(Value::Null));
            }),
        )
        .unwrap();
        comm.task_send("chatty", Value::str("hi")).unwrap().wait(Duration::from_secs(1)).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), Value::str("hi"));
    }

    #[test]
    fn remove_subscribers() {
        let comm = LocalCommunicator::new();
        let t = comm.task_queue("q", 1, Box::new(|_t, ctx| ctx.complete(Ok(Value::Null)))).unwrap();
        comm.remove_task_subscriber(&t).unwrap();
        assert!(comm.remove_task_subscriber(&t).is_err());
        let b = comm.add_broadcast_subscriber(BroadcastFilter::all(), Box::new(|_| {})).unwrap();
        comm.remove_broadcast_subscriber(&b).unwrap();
        assert!(comm.remove_broadcast_subscriber(&b).is_err());
    }
}
