//! Broadcast filters: subscriber-side matching on `sender` and `subject`,
//! with `*` wildcards — mirroring `kiwipy.BroadcastFilter`.

use crate::communicator::BroadcastMessage;

/// A subscriber-side broadcast filter. An unset field matches anything;
/// set fields match with `*` wildcards (any run of characters).
#[derive(Clone, Debug, Default)]
pub struct BroadcastFilter {
    sender: Option<String>,
    subject: Option<String>,
}

impl BroadcastFilter {
    /// Match everything.
    pub fn all() -> Self {
        Self::default()
    }

    /// Require the sender to match `pattern` (supports `*`).
    pub fn sender(mut self, pattern: &str) -> Self {
        self.sender = Some(pattern.to_string());
        self
    }

    /// Require the subject to match `pattern` (supports `*`).
    pub fn subject(mut self, pattern: &str) -> Self {
        self.subject = Some(pattern.to_string());
        self
    }

    /// Does `msg` pass this filter? A message with a missing field fails
    /// any filter constraining that field (kiwiPy behaviour).
    pub fn matches(&self, msg: &BroadcastMessage) -> bool {
        let field_ok = |pattern: &Option<String>, value: &Option<String>| match (pattern, value) {
            (None, _) => true,
            (Some(_), None) => false,
            (Some(p), Some(v)) => wildcard_match(p, v),
        };
        field_ok(&self.sender, &msg.sender) && field_ok(&self.subject, &msg.subject)
    }
}

/// Glob-style match where `*` matches any (possibly empty) run of
/// characters. Linear two-pointer algorithm with backtracking.
pub fn wildcard_match(pattern: &str, text: &str) -> bool {
    let p: Vec<char> = pattern.chars().collect();
    let t: Vec<char> = text.chars().collect();
    let (mut pi, mut ti) = (0usize, 0usize);
    let mut star: Option<(usize, usize)> = None; // (pattern idx after *, text idx)
    while ti < t.len() {
        if pi < p.len() && (p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = Some((pi + 1, ti));
            pi += 1;
        } else if let Some((sp, st)) = star {
            // Backtrack: let the last * eat one more character.
            pi = sp;
            ti = st + 1;
            star = Some((sp, st + 1));
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::{run_prop, Rng};
    use crate::wire::Value;

    fn msg(sender: Option<&str>, subject: Option<&str>) -> BroadcastMessage {
        BroadcastMessage {
            body: Value::Null,
            sender: sender.map(String::from),
            subject: subject.map(String::from),
            correlation_id: None,
        }
    }

    #[test]
    fn empty_filter_matches_everything() {
        let f = BroadcastFilter::all();
        assert!(f.matches(&msg(None, None)));
        assert!(f.matches(&msg(Some("x"), Some("y"))));
    }

    #[test]
    fn subject_filter() {
        let f = BroadcastFilter::all().subject("state_changed.*");
        assert!(f.matches(&msg(None, Some("state_changed.42.finished"))));
        assert!(!f.matches(&msg(None, Some("other.42"))));
        assert!(!f.matches(&msg(None, None)), "missing subject fails a subject filter");
    }

    #[test]
    fn sender_and_subject_are_conjunctive() {
        let f = BroadcastFilter::all().sender("proc-*").subject("*.finished");
        assert!(f.matches(&msg(Some("proc-1"), Some("state.finished"))));
        assert!(!f.matches(&msg(Some("other-1"), Some("state.finished"))));
        assert!(!f.matches(&msg(Some("proc-1"), Some("state.running"))));
    }

    #[test]
    fn wildcard_basics() {
        assert!(wildcard_match("", ""));
        assert!(wildcard_match("*", ""));
        assert!(wildcard_match("*", "anything"));
        assert!(wildcard_match("a*c", "abc"));
        assert!(wildcard_match("a*c", "ac"));
        assert!(wildcard_match("a*c", "axxxc"));
        assert!(!wildcard_match("a*c", "ab"));
        assert!(!wildcard_match("abc", "abcd"));
        assert!(wildcard_match("*.*", "a.b"));
        assert!(wildcard_match("a*b*c", "a-x-b-y-c"));
        assert!(!wildcard_match("a*b*c", "acb"));
    }

    #[test]
    fn prop_star_matches_any_split() {
        run_prop("wildcard star", |rng: &Rng| {
            let prefix = rng.string(6);
            let middle = rng.string(6);
            let suffix = rng.string(6);
            let pattern = format!("{prefix}*{suffix}");
            let text = format!("{prefix}{middle}{suffix}");
            assert!(wildcard_match(&pattern, &text), "pattern {pattern} text {text}");
        });
    }

    #[test]
    fn prop_literal_pattern_is_equality() {
        run_prop("wildcard literal", |rng: &Rng| {
            let a: String = rng.string(8).replace('*', "x");
            let b: String = rng.string(8).replace('*', "y");
            assert!(wildcard_match(&a, &a));
            if a != b {
                assert_eq!(wildcard_match(&a, &b), false);
            }
        });
    }
}
