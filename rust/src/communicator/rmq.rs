//! [`RmqCommunicator`]: kiwiPy's `RmqThreadCommunicator` equivalent — the
//! three message types implemented over the broker, usable from plain
//! blocking code while a hidden communication thread does the work.
//!
//! Mapping onto broker primitives (identical to how kiwiPy maps onto AMQP):
//!
//! * **task queue** — a durable queue on the default exchange; tasks are
//!   published `persistent` with `reply_to`/`correlation_id`; consumers use
//!   prefetch and explicit ack-after-completion, so a dead worker's tasks
//!   are requeued by the broker.
//! * **RPC** — a direct exchange (`kiwi.rpc`); each subscriber binds an
//!   exclusive queue under its identifier; `mandatory` publish turns
//!   "nobody bound" into [`Error::UnroutableMessage`].
//! * **broadcast** — a fanout exchange (`kiwi.broadcast`); every subscriber
//!   binds its own exclusive queue; filtering is subscriber-side
//!   ([`BroadcastFilter`]), exactly like kiwiPy.
//!
//! Acks are pipelined end-to-end: when the broker coalesces a backlog into
//! a delivery batch, every `ctx.complete(..)` / reply-consumer ack issued
//! while that batch is dispatched buffers in the connection's ack window
//! and leaves as a single `AckMulti` frame — one write for the whole
//! batch's worth of acks instead of one per message.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::broker::protocol::{
    ClientRequest, ExchangeKind, MessageProps, OverflowPolicy, QueueOptions,
};
use crate::communicator::filters::BroadcastFilter;
use crate::communicator::futures::{promise, KiwiFuture, Promise};
use crate::communicator::{
    unique_id, BroadcastHandler, BroadcastMessage, Communicator, RpcHandler, TaskHandler,
};
use crate::error::{Error, Result};
use crate::transport::{tcp_factory, Connection, ConnectionConfig, Link, LinkFactory};
use crate::wire::{Bytes, Value};

/// Exchange names and client tuning.
#[derive(Clone, Debug)]
pub struct RmqConfig {
    pub client_id: String,
    /// Heartbeat interval; 0 disables (see [`ConnectionConfig`]).
    pub heartbeat_ms: u64,
    pub request_timeout: Duration,
    pub rpc_exchange: String,
    pub broadcast_exchange: String,
    /// Declare task queues durable (persistent tasks). On by default —
    /// this is the paper's headline robustness property.
    pub durable_tasks: bool,
    /// Wait for the broker's ack on every `task_send` publish (publisher
    /// confirms). On = submission errors surface immediately; off =
    /// pipelined fire-and-forget submission, ~an RTT faster per task
    /// (§Perf E1b). Unroutable drops are still impossible once the queue
    /// is declared, which `task_send` guarantees.
    pub confirm_publishes: bool,
    /// Max delivery attempts per task; a task nack-requeued at this count
    /// (or whose worker keeps crashing) is dead-lettered instead of
    /// redelivered forever. `None` = unlimited (seed behaviour).
    pub task_max_delivery: Option<u32>,
    /// Dead-letter exchange for task queues. When set, this communicator
    /// declares the exchange (direct), a `<queue>.dlq` catch queue bound
    /// under the task queue's name, and declares task queues with the DLX
    /// attached — poisoned/expired/overflowed tasks land on the catch
    /// queue with `x-death` metadata instead of vanishing.
    pub task_dead_letter_exchange: Option<String>,
    /// Bound on task-queue depth (backpressure), applied with
    /// `task_overflow`.
    pub task_max_length: Option<usize>,
    /// What a full task queue does: evict the oldest task (`drop-head`)
    /// or refuse the incoming one (`reject-new` — a confirming
    /// `task_send` then surfaces the refusal to the submitter).
    pub task_overflow: OverflowPolicy,
    /// Consecutive failed re-dials before a factory-connected communicator
    /// gives up on an outage (0 disables reconnection). Ignored for
    /// communicators connected over a bare link.
    pub reconnect_max_retries: u32,
    /// Base reconnect backoff (capped exponential + jitter; see
    /// [`ConnectionConfig::reconnect_backoff_ms`]).
    pub reconnect_backoff_ms: u64,
}

impl Default for RmqConfig {
    fn default() -> Self {
        RmqConfig {
            client_id: unique_id("kiwi"),
            heartbeat_ms: 0,
            request_timeout: Duration::from_secs(10),
            rpc_exchange: "kiwi.rpc".into(),
            broadcast_exchange: "kiwi.broadcast".into(),
            durable_tasks: true,
            confirm_publishes: true,
            task_max_delivery: None,
            task_dead_letter_exchange: None,
            task_max_length: None,
            task_overflow: OverflowPolicy::DropHead,
            reconnect_max_retries: 8,
            reconnect_backoff_ms: 250,
        }
    }
}

/// Conventional name of the catch queue this communicator binds to its
/// dead-letter exchange for `queue` (see
/// [`RmqConfig::task_dead_letter_exchange`]).
pub fn dead_letter_queue_name(queue: &str) -> String {
    format!("{queue}.dlq")
}

enum Subscription {
    Task { consumer_tag: String },
    Broadcast { consumer_tag: String, queue: String },
    Rpc { consumer_tag: String, queue: String },
}

struct Shared {
    /// correlation_id -> reply promise (task results and RPC responses).
    pending: Mutex<HashMap<String, Promise<Value>>>,
}

/// The broker-backed communicator.
pub struct RmqCommunicator {
    conn: Arc<Connection>,
    config: RmqConfig,
    reply_queue: String,
    shared: Arc<Shared>,
    subscriptions: Mutex<HashMap<String, Subscription>>,
    /// Task queues already declared by this communicator (declare-once).
    declared: Mutex<HashSet<String>>,
    /// RPC identifiers registered locally (duplicate detection).
    rpc_ids: Mutex<HashMap<String, Subscription>>,
}

impl RmqCommunicator {
    /// Connect over an existing [`Link`] (TCP or in-process). A link
    /// failure permanently closes this communicator; use
    /// [`RmqCommunicator::connect_with_factory`] (or
    /// [`RmqCommunicator::connect_tcp`]) for a communicator that survives
    /// broker outages.
    pub fn connect(link: Arc<dyn Link>, config: RmqConfig) -> Result<Self> {
        let conn = Arc::new(Connection::open(link, Self::conn_config(&config))?);
        Self::bootstrap(conn, config)
    }

    /// Connect through a re-dialing [`LinkFactory`]: on link death the
    /// underlying connection reconnects with backoff and replays its
    /// topology journal, so task subscriptions, RPC reply queues and
    /// broadcast bindings are all re-established with no user code — a
    /// daemon keeps consuming across a full broker restart.
    pub fn connect_with_factory(factory: LinkFactory, config: RmqConfig) -> Result<Self> {
        let conn = Arc::new(Connection::open_with_factory(factory, Self::conn_config(&config))?);
        Self::bootstrap(conn, config)
    }

    /// Convenience: a reconnecting communicator dialing `addr` over TCP.
    pub fn connect_tcp(addr: impl Into<String>, config: RmqConfig) -> Result<Self> {
        Self::connect_with_factory(tcp_factory(addr), config)
    }

    fn conn_config(config: &RmqConfig) -> ConnectionConfig {
        ConnectionConfig {
            client_id: config.client_id.clone(),
            heartbeat_ms: config.heartbeat_ms,
            request_timeout: config.request_timeout,
            reconnect_max_retries: config.reconnect_max_retries,
            reconnect_backoff_ms: config.reconnect_backoff_ms,
        }
    }

    fn bootstrap(conn: Arc<Connection>, config: RmqConfig) -> Result<Self> {
        // Topology: the two shared exchanges.
        conn.request(&ClientRequest::ExchangeDeclare {
            exchange: config.rpc_exchange.clone(),
            kind: ExchangeKind::Direct,
        })?;
        conn.request(&ClientRequest::ExchangeDeclare {
            exchange: config.broadcast_exchange.clone(),
            kind: ExchangeKind::Fanout,
        })?;
        // Private reply queue for task results and RPC responses.
        let reply_queue = unique_id(&format!("reply.{}", config.client_id));
        conn.request(&ClientRequest::QueueDeclare {
            queue: reply_queue.clone(),
            options: QueueOptions { exclusive: true, ..Default::default() },
        })?;
        let shared = Arc::new(Shared { pending: Mutex::new(HashMap::new()) });
        let shared2 = Arc::clone(&shared);
        let conn2 = Arc::clone(&conn);
        let reply_tag = unique_id("replyc");
        conn.consume(
            &reply_queue,
            &reply_tag,
            0,
            Box::new(move |d| {
                conn2.ack(d.delivery_tag).ok();
                let Some(corr) = d.props.correlation_id.as_deref() else {
                    log::warn!("rmq: reply without correlation_id dropped");
                    return;
                };
                let Some(p) = shared2.pending.lock().unwrap().remove(corr) else {
                    // Late reply for a timed-out/abandoned future.
                    return;
                };
                // Lazy decode: the reply body stays encoded until here,
                // the one place that actually needs the value tree.
                match d.body.decode().and_then(|v| decode_reply(&v)) {
                    Ok(v) => p.set_result(v),
                    Err(e) => p.set_error(e),
                };
            }),
        )?;
        Ok(RmqCommunicator {
            conn,
            config,
            reply_queue,
            shared,
            subscriptions: Mutex::new(HashMap::new()),
            declared: Mutex::new(HashSet::new()),
            rpc_ids: Mutex::new(HashMap::new()),
        })
    }

    /// The underlying connection (used by the daemon for raw operations).
    pub fn connection(&self) -> &Arc<Connection> {
        &self.conn
    }

    /// Client-side metrics (`client.reconnects_total`,
    /// `client.replayed_consumers_total`).
    pub fn metrics(&self) -> &crate::metrics::Registry {
        self.conn.metrics()
    }

    /// Declare a task queue once per communicator, wiring up the
    /// dead-letter topology first when configured: the DLX (direct), the
    /// `<queue>.dlq` catch queue, and its binding under the task queue's
    /// name — dead tasks keep their original routing key, so a direct DLX
    /// funnels each queue's casualties into its own catch queue.
    fn ensure_task_queue(&self, queue: &str) -> Result<()> {
        {
            let declared = self.declared.lock().unwrap();
            if declared.contains(queue) {
                return Ok(());
            }
        }
        if let Some(dlx) = &self.config.task_dead_letter_exchange {
            let dlq = dead_letter_queue_name(queue);
            self.conn.request(&ClientRequest::ExchangeDeclare {
                exchange: dlx.clone(),
                kind: ExchangeKind::Direct,
            })?;
            self.conn.request(&ClientRequest::QueueDeclare {
                queue: dlq.clone(),
                options: QueueOptions {
                    durable: self.config.durable_tasks,
                    ..Default::default()
                },
            })?;
            self.conn.request(&ClientRequest::Bind {
                exchange: dlx.clone(),
                queue: dlq,
                routing_key: queue.to_string(),
            })?;
        }
        self.conn.request(&ClientRequest::QueueDeclare {
            queue: queue.to_string(),
            options: QueueOptions {
                durable: self.config.durable_tasks,
                max_delivery: self.config.task_max_delivery,
                dead_letter_exchange: self.config.task_dead_letter_exchange.clone(),
                max_length: self.config.task_max_length,
                overflow: self.config.task_overflow,
                ..Default::default()
            },
        })?;
        self.declared.lock().unwrap().insert(queue.to_string());
        Ok(())
    }

    fn register_pending(&self) -> (String, KiwiFuture<Value>) {
        let corr = unique_id("corr");
        let (p, f) = promise();
        self.shared.pending.lock().unwrap().insert(corr.clone(), p);
        (corr, f)
    }

    /// Graceful close (also runs on drop).
    pub fn close(&self) {
        self.conn.close();
    }
}

impl Drop for RmqCommunicator {
    fn drop(&mut self) {
        // Delivery-handler closures hold `Arc<Connection>` clones, so the
        // connection would never drop on its own — close explicitly, which
        // also clears those handlers.
        self.conn.close();
    }
}

fn decode_reply(body: &Value) -> Result<Value> {
    match body.get_str("status")? {
        "ok" => Ok(body.get("result")?.clone()),
        "err" => Err(Error::RemoteException(format!(
            "{}: {}",
            body.get_opt("code").and_then(|c| c.as_str().ok().map(String::from)).unwrap_or_default(),
            body.get_str("message").unwrap_or("<no message>")
        ))),
        other => Err(Error::Wire(format!("unknown reply status '{other}'"))),
    }
}

fn encode_reply(result: &Result<Value>) -> Value {
    match result {
        Ok(v) => Value::map([("status", Value::str("ok")), ("result", v.clone())]),
        Err(e) => Value::map([
            ("status", Value::str("err")),
            ("code", Value::str(e.code())),
            ("message", Value::str(e.to_string())),
        ]),
    }
}

/// Handed to task handlers; completion may happen on any thread (the
/// daemon's worker pool completes from workers). Consumes itself: each
/// task is completed or rejected exactly once.
pub struct TaskContext {
    inner: ContextInner,
}

enum ContextInner {
    Remote {
        conn: Arc<Connection>,
        delivery_tag: u64,
        reply_to: Option<String>,
        correlation_id: Option<String>,
    },
    Local {
        promise: Promise<Value>,
    },
}

impl TaskContext {
    pub(crate) fn remote(
        conn: Arc<Connection>,
        delivery_tag: u64,
        reply_to: Option<String>,
        correlation_id: Option<String>,
    ) -> Self {
        TaskContext {
            inner: ContextInner::Remote { conn, delivery_tag, reply_to, correlation_id },
        }
    }

    pub(crate) fn local(promise: Promise<Value>) -> Self {
        TaskContext { inner: ContextInner::Local { promise } }
    }

    /// Finish the task: reply to the sender (if it asked) and ack, so the
    /// broker retires the message from the task queue.
    pub fn complete(self, result: Result<Value>) {
        match self.inner {
            ContextInner::Remote { conn, delivery_tag, reply_to, correlation_id } => {
                if let (Some(rq), Some(corr)) = (reply_to, correlation_id) {
                    conn.send_noreply(&ClientRequest::Publish {
                        exchange: String::new(),
                        routing_key: rq,
                        body: Bytes::encode(&encode_reply(&result)),
                        props: MessageProps {
                            correlation_id: Some(corr),
                            ..Default::default()
                        }
                        .into(),
                        // Not mandatory: sender may be gone; that's fine.
                        mandatory: false,
                    })
                    .ok();
                }
                conn.ack(delivery_tag).ok();
            }
            ContextInner::Local { promise } => {
                match result {
                    Ok(v) => promise.set_result(v),
                    Err(e) => promise.set_error(e),
                };
            }
        }
    }

    /// Refuse the task. With `requeue` the broker hands it to another
    /// consumer (until the queue's `max_delivery` cap says otherwise);
    /// with `requeue = false` this is the poison pill — the broker
    /// dead-letters the task to the queue's DLX (or drops it when none is
    /// configured) instead of redelivering it forever.
    pub fn reject(self, requeue: bool) {
        match self.inner {
            ContextInner::Remote { conn, delivery_tag, .. } => {
                conn.reject(delivery_tag, requeue).ok();
            }
            ContextInner::Local { promise } => {
                promise.set_error(Error::RemoteException("task rejected".into()));
            }
        }
    }
}

impl Communicator for RmqCommunicator {
    fn task_send(&self, queue: &str, task: Value) -> Result<KiwiFuture<Value>> {
        self.ensure_task_queue(queue)?;
        let (corr, future) = self.register_pending();
        // The single encode of this task's lifetime: broker routing, WAL
        // records and every delivery share the buffer built here.
        let publish = ClientRequest::Publish {
            exchange: String::new(),
            routing_key: queue.to_string(),
            body: Bytes::encode(&task),
            props: MessageProps {
                correlation_id: Some(corr.clone()),
                reply_to: Some(self.reply_queue.clone()),
                persistent: self.config.durable_tasks,
                ..Default::default()
            }
            .into(),
            mandatory: true,
        };
        let res = if self.config.confirm_publishes {
            self.conn.request(&publish).map(|_| ())
        } else {
            // Pipelined: the queue is declared (above), so the publish
            // cannot be unroutable; skip the confirm round-trip.
            self.conn.send_noreply(&publish)
        };
        if let Err(e) = res {
            self.shared.pending.lock().unwrap().remove(&corr);
            return Err(e);
        }
        Ok(future)
    }

    fn task_queue(&self, queue: &str, prefetch: u32, mut handler: TaskHandler) -> Result<String> {
        self.ensure_task_queue(queue)?;
        let consumer_tag = unique_id("task");
        let conn = Arc::clone(&self.conn);
        self.conn.consume(
            queue,
            &consumer_tag,
            prefetch,
            Box::new(move |d| {
                let ctx = TaskContext::remote(
                    Arc::clone(&conn),
                    d.delivery_tag,
                    d.props.reply_to.clone(),
                    d.props.correlation_id.clone(),
                );
                // Decode-on-demand at the consumer — the first (and only)
                // decode of the task body since the sender encoded it.
                match d.body.decode() {
                    Ok(task) => handler(task, ctx),
                    Err(e) => {
                        // Complete with the error (reply + ack) so the
                        // sender's future resolves instead of hanging,
                        // mirroring the RPC path's decode-failure handling.
                        log::warn!("rmq: undecodable task body dropped: {e}");
                        ctx.complete(Err(e));
                    }
                }
            }),
        )?;
        self.subscriptions
            .lock()
            .unwrap()
            .insert(consumer_tag.clone(), Subscription::Task { consumer_tag: consumer_tag.clone() });
        Ok(consumer_tag)
    }

    fn remove_task_subscriber(&self, subscription_id: &str) -> Result<()> {
        let sub = self.subscriptions.lock().unwrap().remove(subscription_id);
        match sub {
            Some(Subscription::Task { consumer_tag }) => self.conn.cancel(&consumer_tag),
            _ => Err(Error::Broker(format!("no task subscription '{subscription_id}'"))),
        }
    }

    fn rpc_send(&self, recipient_id: &str, msg: Value) -> Result<KiwiFuture<Value>> {
        let (corr, future) = self.register_pending();
        let res = self.conn.request(&ClientRequest::Publish {
            exchange: self.config.rpc_exchange.clone(),
            routing_key: recipient_id.to_string(),
            body: Bytes::encode(&msg),
            props: MessageProps {
                correlation_id: Some(corr.clone()),
                reply_to: Some(self.reply_queue.clone()),
                ..Default::default()
            }
            .into(),
            mandatory: true, // nobody listening -> UnroutableMessage
        });
        if let Err(e) = res {
            self.shared.pending.lock().unwrap().remove(&corr);
            return Err(e);
        }
        Ok(future)
    }

    fn add_rpc_subscriber(&self, identifier: &str, mut handler: RpcHandler) -> Result<()> {
        let mut rpc_ids = self.rpc_ids.lock().unwrap();
        if rpc_ids.contains_key(identifier) {
            return Err(Error::DuplicateSubscriber(identifier.to_string()));
        }
        let queue = unique_id(&format!("rpc.{identifier}"));
        self.conn.request(&ClientRequest::QueueDeclare {
            queue: queue.clone(),
            options: QueueOptions { exclusive: true, ..Default::default() },
        })?;
        self.conn.request(&ClientRequest::Bind {
            exchange: self.config.rpc_exchange.clone(),
            queue: queue.clone(),
            routing_key: identifier.to_string(),
        })?;
        let consumer_tag = unique_id("rpcc");
        let conn = Arc::clone(&self.conn);
        self.conn.consume(
            &queue,
            &consumer_tag,
            0,
            Box::new(move |d| {
                // Lazy decode, then the user handler; a decode error is
                // reported back to the caller like a handler error.
                let result = match d.body.decode() {
                    Ok(v) => handler(v),
                    Err(e) => Err(e),
                };
                if let (Some(rq), Some(corr)) =
                    (d.props.reply_to.clone(), d.props.correlation_id.clone())
                {
                    conn.send_noreply(&ClientRequest::Publish {
                        exchange: String::new(),
                        routing_key: rq,
                        body: Bytes::encode(&encode_reply(&result)),
                        props: MessageProps { correlation_id: Some(corr), ..Default::default() }
                            .into(),
                        mandatory: false,
                    })
                    .ok();
                }
                conn.ack(d.delivery_tag).ok();
            }),
        )?;
        rpc_ids.insert(
            identifier.to_string(),
            Subscription::Rpc { consumer_tag, queue },
        );
        Ok(())
    }

    fn remove_rpc_subscriber(&self, identifier: &str) -> Result<()> {
        let sub = self.rpc_ids.lock().unwrap().remove(identifier);
        match sub {
            Some(Subscription::Rpc { consumer_tag, queue }) => {
                self.conn.cancel(&consumer_tag)?;
                self.conn.request(&ClientRequest::QueueDelete { queue })?;
                Ok(())
            }
            _ => Err(Error::Broker(format!("no rpc subscriber '{identifier}'"))),
        }
    }

    fn broadcast_send(
        &self,
        body: Value,
        sender: Option<&str>,
        subject: Option<&str>,
    ) -> Result<()> {
        let msg = BroadcastMessage {
            body,
            sender: sender.map(String::from),
            subject: subject.map(String::from),
            correlation_id: None,
        };
        // Broadcasts are fire-and-forget by definition; never wait for a
        // confirm (§Perf: halves the E3 sender-side cost). One encode here
        // feeds every subscriber's delivery.
        self.conn.send_noreply(&ClientRequest::Publish {
            exchange: self.config.broadcast_exchange.clone(),
            routing_key: subject.unwrap_or("").to_string(),
            body: Bytes::encode(&msg.to_value()),
            props: MessageProps::default().into(),
            mandatory: false, // zero subscribers is fine
        })?;
        Ok(())
    }

    fn add_broadcast_subscriber(
        &self,
        filter: BroadcastFilter,
        mut handler: BroadcastHandler,
    ) -> Result<String> {
        let queue = unique_id("bc");
        self.conn.request(&ClientRequest::QueueDeclare {
            queue: queue.clone(),
            options: QueueOptions { exclusive: true, ..Default::default() },
        })?;
        self.conn.request(&ClientRequest::Bind {
            exchange: self.config.broadcast_exchange.clone(),
            queue: queue.clone(),
            routing_key: "".to_string(),
        })?;
        let consumer_tag = unique_id("bcc");
        let conn = Arc::clone(&self.conn);
        self.conn.consume(
            &queue,
            &consumer_tag,
            0,
            Box::new(move |d| {
                conn.ack(d.delivery_tag).ok();
                match d.body.decode().and_then(|v| BroadcastMessage::from_value(&v)) {
                    Ok(msg) => {
                        if filter.matches(&msg) {
                            handler(msg);
                        }
                    }
                    Err(e) => log::warn!("broadcast: undecodable message: {e}"),
                }
            }),
        )?;
        let sub_id = unique_id("bcsub");
        self.subscriptions
            .lock()
            .unwrap()
            .insert(sub_id.clone(), Subscription::Broadcast { consumer_tag, queue });
        Ok(sub_id)
    }

    fn remove_broadcast_subscriber(&self, subscription_id: &str) -> Result<()> {
        let sub = self.subscriptions.lock().unwrap().remove(subscription_id);
        match sub {
            Some(Subscription::Broadcast { consumer_tag, queue }) => {
                self.conn.cancel(&consumer_tag)?;
                self.conn.request(&ClientRequest::QueueDelete { queue })?;
                Ok(())
            }
            _ => Err(Error::Broker(format!("no broadcast subscription '{subscription_id}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::InprocBroker;

    fn comm(broker: &InprocBroker) -> RmqCommunicator {
        RmqCommunicator::connect(broker.connect(), RmqConfig::default()).unwrap()
    }

    #[test]
    fn task_roundtrip_with_result() {
        let broker = InprocBroker::new();
        let worker = comm(&broker);
        let client = comm(&broker);
        worker
            .task_queue(
                "sq",
                1,
                Box::new(|task, ctx| {
                    let x = task.get_i64("x").unwrap();
                    ctx.complete(Ok(Value::map([("y", Value::I64(x * x))])));
                }),
            )
            .unwrap();
        let fut = client.task_send("sq", Value::map([("x", Value::I64(7))])).unwrap();
        let result = fut.wait(Duration::from_secs(5)).unwrap();
        assert_eq!(result.get_i64("y").unwrap(), 49);
    }

    #[test]
    fn tasks_distributed_across_workers() {
        let broker = InprocBroker::new();
        let client = comm(&broker);
        let w1 = comm(&broker);
        let w2 = comm(&broker);
        let make_handler = |name: &'static str| -> TaskHandler {
            Box::new(move |_task, ctx| {
                ctx.complete(Ok(Value::str(name)));
            })
        };
        w1.task_queue("work", 1, make_handler("w1")).unwrap();
        w2.task_queue("work", 1, make_handler("w2")).unwrap();
        let futs: Vec<_> =
            (0..10).map(|i| client.task_send("work", Value::I64(i)).unwrap()).collect();
        let mut counts = std::collections::HashMap::new();
        for f in futs {
            let who = f.wait(Duration::from_secs(5)).unwrap();
            *counts.entry(who.as_str().unwrap().to_string()).or_insert(0) += 1;
        }
        assert_eq!(counts["w1"] + counts["w2"], 10);
        assert!(counts["w1"] > 0 && counts["w2"] > 0, "both workers should get tasks: {counts:?}");
    }

    #[test]
    fn task_handler_error_propagates_to_sender() {
        let broker = InprocBroker::new();
        let worker = comm(&broker);
        let client = comm(&broker);
        worker
            .task_queue(
                "fail",
                1,
                Box::new(|_task, ctx| {
                    ctx.complete(Err(Error::RemoteException("task blew up".into())));
                }),
            )
            .unwrap();
        let fut = client.task_send("fail", Value::Null).unwrap();
        match fut.wait(Duration::from_secs(5)) {
            Err(Error::RemoteException(m)) => assert!(m.contains("task blew up")),
            other => panic!("expected remote exception, got {other:?}"),
        }
    }

    #[test]
    fn rpc_roundtrip() {
        let broker = InprocBroker::new();
        let server = comm(&broker);
        let client = comm(&broker);
        server
            .add_rpc_subscriber(
                "proc-42",
                Box::new(|msg| {
                    assert_eq!(msg.as_str().unwrap(), "pause");
                    Ok(Value::str("paused"))
                }),
            )
            .unwrap();
        let reply = client
            .rpc_send("proc-42", Value::str("pause"))
            .unwrap()
            .wait(Duration::from_secs(5))
            .unwrap();
        assert_eq!(reply, Value::str("paused"));
    }

    #[test]
    fn rpc_to_nobody_is_unroutable() {
        let broker = InprocBroker::new();
        let client = comm(&broker);
        match client.rpc_send("ghost", Value::Null) {
            Err(Error::UnroutableMessage(_)) => {}
            Err(other) => panic!("expected unroutable, got {other:?}"),
            Ok(_) => panic!("expected unroutable, got a future"),
        }
    }

    #[test]
    fn rpc_handler_error_propagates() {
        let broker = InprocBroker::new();
        let server = comm(&broker);
        let client = comm(&broker);
        server
            .add_rpc_subscriber(
                "x",
                Box::new(|_| Err(Error::InvalidStateTransition {
                    from: "finished".into(),
                    event: "play".into(),
                })),
            )
            .unwrap();
        let res = client.rpc_send("x", Value::Null).unwrap().wait(Duration::from_secs(5));
        assert!(matches!(res, Err(Error::RemoteException(_))));
    }

    #[test]
    fn duplicate_rpc_subscriber_rejected() {
        let broker = InprocBroker::new();
        let server = comm(&broker);
        server.add_rpc_subscriber("id", Box::new(|_| Ok(Value::Null))).unwrap();
        assert!(matches!(
            server.add_rpc_subscriber("id", Box::new(|_| Ok(Value::Null))),
            Err(Error::DuplicateSubscriber(_))
        ));
    }

    #[test]
    fn remove_rpc_subscriber_makes_unroutable() {
        let broker = InprocBroker::new();
        let server = comm(&broker);
        let client = comm(&broker);
        server.add_rpc_subscriber("temp", Box::new(|_| Ok(Value::Null))).unwrap();
        client.rpc_send("temp", Value::Null).unwrap().wait(Duration::from_secs(5)).unwrap();
        server.remove_rpc_subscriber("temp").unwrap();
        assert!(matches!(
            client.rpc_send("temp", Value::Null),
            Err(Error::UnroutableMessage(_))
        ));
    }

    #[test]
    fn broadcast_reaches_all_subscribers() {
        let broker = InprocBroker::new();
        let sender = comm(&broker);
        let sub1 = comm(&broker);
        let sub2 = comm(&broker);
        let (tx1, rx1) = std::sync::mpsc::channel();
        let (tx2, rx2) = std::sync::mpsc::channel();
        sub1.add_broadcast_subscriber(
            BroadcastFilter::all(),
            Box::new(move |m| tx1.send(m).unwrap()),
        )
        .unwrap();
        sub2.add_broadcast_subscriber(
            BroadcastFilter::all(),
            Box::new(move |m| tx2.send(m).unwrap()),
        )
        .unwrap();
        sender.broadcast_send(Value::str("hello"), Some("me"), Some("greeting")).unwrap();
        let m1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap();
        let m2 = rx2.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(m1.body, Value::str("hello"));
        assert_eq!(m2.subject.as_deref(), Some("greeting"));
    }

    #[test]
    fn broadcast_filter_applied() {
        let broker = InprocBroker::new();
        let sender = comm(&broker);
        let sub = comm(&broker);
        let (tx, rx) = std::sync::mpsc::channel();
        sub.add_broadcast_subscriber(
            BroadcastFilter::all().subject("state.*.finished"),
            Box::new(move |m| tx.send(m).unwrap()),
        )
        .unwrap();
        sender.broadcast_send(Value::I64(1), None, Some("state.7.running")).unwrap();
        sender.broadcast_send(Value::I64(2), None, Some("state.7.finished")).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got.body, Value::I64(2), "filtered-out message must not arrive first");
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err());
    }

    #[test]
    fn broadcast_to_nobody_is_fine() {
        let broker = InprocBroker::new();
        let sender = comm(&broker);
        sender.broadcast_send(Value::Null, None, None).unwrap();
    }

    #[test]
    fn remove_broadcast_subscriber_stops_delivery() {
        let broker = InprocBroker::new();
        let sender = comm(&broker);
        let sub = comm(&broker);
        let (tx, rx) = std::sync::mpsc::channel();
        let id = sub
            .add_broadcast_subscriber(
                BroadcastFilter::all(),
                Box::new(move |m| tx.send(m).unwrap()),
            )
            .unwrap();
        sender.broadcast_send(Value::I64(1), None, None).unwrap();
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        sub.remove_broadcast_subscriber(&id).unwrap();
        sender.broadcast_send(Value::I64(2), None, None).unwrap();
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err());
    }

    #[test]
    fn worker_death_requeues_task_to_survivor() {
        // The paper's §I.A claim, at the communicator level.
        let broker = InprocBroker::new();
        let client = comm(&broker);
        // Worker 1 takes the task and "crashes" (never acks, connection drops).
        let doomed = comm(&broker);
        let (got_tx, got_rx) = std::sync::mpsc::channel();
        doomed
            .task_queue(
                "fragile",
                1,
                Box::new(move |_t, _ctx| {
                    // Deliberately leak the context without completing:
                    // simulates a crash mid-task. (Dropping ctx without
                    // complete leaves the message unacked.)
                    got_tx.send(()).unwrap();
                }),
            )
            .unwrap();
        let fut = client.task_send("fragile", Value::str("survive-me")).unwrap();
        got_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // Kill the doomed worker abruptly.
        drop(doomed);
        // A healthy worker arrives and completes the requeued task.
        let survivor = comm(&broker);
        survivor
            .task_queue(
                "fragile",
                1,
                Box::new(|t, ctx| {
                    ctx.complete(Ok(t));
                }),
            )
            .unwrap();
        let result = fut.wait(Duration::from_secs(5)).unwrap();
        assert_eq!(result, Value::str("survive-me"));
    }
}
