//! Command-line interface: `kiwi broker|worker|submit|ctl|status`.
//! (clap is unavailable offline; `args` is a small tested parser.)

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::run;
