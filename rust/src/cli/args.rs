//! Minimal argument parser: `cmd subcommand --key value --flag positional`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Option names that take a value (everything else starting `--` is a flag).
const VALUED: &[&str] = &[
    "config", "addr", "workers", "heartbeat-ms", "queue", "process", "inputs", "pid", "reason",
    "artifacts", "checkpoints", "wal", "n-volumes", "lattice-a", "timeout-ms", "shards",
    "delivery-batch", "route-cache", "max-delivery", "dead-letter-exchange", "max-length",
    "overflow", "reconnect-max-retries", "reconnect-backoff-ms", "net", "event-batch",
    "outbox-cap", "wal-segments", "wal-commit-interval-us", "page-out-threshold",
    "page-in-batch", "publish-credit", "default-prefetch", "workflow-workers",
    "max-resident-processes",
];

impl Args {
    /// Parse, skipping `argv[0]`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().skip(1).peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if VALUED.contains(&name) {
                    let v = iter
                        .next()
                        .ok_or_else(|| Error::Config(format!("--{name} needs a value")))?;
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        self.opt(name)
            .map(|v| {
                v.parse::<T>()
                    .map_err(|_| Error::Config(format!("--{name}: cannot parse '{v}'")))
            })
            .transpose()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("kiwi worker --workers 8 --addr 1.2.3.4:5 --verbose extra");
        assert_eq!(a.subcommand.as_deref(), Some("worker"));
        assert_eq!(a.opt("workers"), Some("8"));
        assert_eq!(a.opt("addr"), Some("1.2.3.4:5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("kiwi submit --process=eos --n-volumes=8");
        assert_eq!(a.opt("process"), Some("eos"));
        assert_eq!(a.opt("n-volumes"), Some("8"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["kiwi".into(), "--addr".into()]).is_err());
    }

    #[test]
    fn sharding_options_take_values() {
        let a = parse("kiwi broker --shards 8 --delivery-batch 128 --route-cache 1024");
        assert_eq!(a.opt_parse::<usize>("shards").unwrap(), Some(8));
        assert_eq!(a.opt_parse::<usize>("delivery-batch").unwrap(), Some(128));
        assert_eq!(a.opt_parse::<usize>("route-cache").unwrap(), Some(1024));
    }

    #[test]
    fn lifecycle_options_take_values() {
        let a = parse(
            "kiwi worker --max-delivery 3 --dead-letter-exchange kiwi.dlx \
             --max-length 500 --overflow reject-new",
        );
        assert_eq!(a.opt_parse::<u32>("max-delivery").unwrap(), Some(3));
        assert_eq!(a.opt("dead-letter-exchange"), Some("kiwi.dlx"));
        assert_eq!(a.opt_parse::<usize>("max-length").unwrap(), Some(500));
        assert_eq!(a.opt("overflow"), Some("reject-new"));
    }

    #[test]
    fn reconnect_options_take_values() {
        let a = parse("kiwi worker --reconnect-max-retries 12 --reconnect-backoff-ms 100");
        assert_eq!(a.opt_parse::<u32>("reconnect-max-retries").unwrap(), Some(12));
        assert_eq!(a.opt_parse::<u64>("reconnect-backoff-ms").unwrap(), Some(100));
    }

    #[test]
    fn wal_options_take_values() {
        let a = parse("kiwi broker --wal-segments 8 --wal-commit-interval-us 250");
        assert_eq!(a.opt_parse::<usize>("wal-segments").unwrap(), Some(8));
        assert_eq!(a.opt_parse::<u64>("wal-commit-interval-us").unwrap(), Some(250));
    }

    #[test]
    fn net_options_take_values() {
        let a = parse("kiwi broker --net threads --event-batch 128 --outbox-cap 65536");
        assert_eq!(a.opt("net"), Some("threads"));
        assert_eq!(a.opt_parse::<usize>("event-batch").unwrap(), Some(128));
        assert_eq!(a.opt_parse::<usize>("outbox-cap").unwrap(), Some(65536));
    }

    #[test]
    fn workflow_options_take_values() {
        let a = parse("kiwi worker --workflow-workers 4 --max-resident-processes 50000");
        assert_eq!(a.opt_parse::<usize>("workflow-workers").unwrap(), Some(4));
        assert_eq!(a.opt_parse::<usize>("max-resident-processes").unwrap(), Some(50000));
    }

    #[test]
    fn typed_parse() {
        let a = parse("kiwi worker --workers 8");
        assert_eq!(a.opt_parse::<usize>("workers").unwrap(), Some(8));
        assert_eq!(a.opt_parse::<usize>("missing").unwrap(), None);
        let b = parse("kiwi worker --workers eight");
        assert!(b.opt_parse::<usize>("workers").is_err());
    }
}
