//! Subcommand implementations. Each maps onto a deployment role:
//!
//! * `kiwi broker`  — run the message broker (durable via WAL).
//! * `kiwi worker`  — run a daemon consuming the task queue.
//! * `kiwi submit`  — launch a process (e.g. the EOS workchain) and wait.
//! * `kiwi ctl`     — pause/play/kill/status a live process over RPC.
//! * `kiwi status`  — broker status snapshot.

use std::sync::Arc;
use std::time::Duration;

use crate::broker::core::BrokerHandle;
use crate::broker::persistence::{RecoveredState, SegmentedWal};
use crate::broker::protocol::ClientRequest;
use crate::broker::BrokerServer;
use crate::cli::args::Args;
use crate::communicator::{Communicator, RmqCommunicator, RmqConfig};
use crate::config::Config;
use crate::daemon::Daemon;
use crate::error::{Error, Result};
use crate::payload::register_payload_processes;
use crate::runtime::Engine;
use crate::transport::{connect_tcp, Connection, ConnectionConfig};
use crate::wire::{json, Value};
use crate::workflow::checkpoint::FileCheckpointStore;
use crate::workflow::registry::ProcessRegistry;
use crate::workflow::{ProcessController, RemoteLauncher};

const USAGE: &str = "\
kiwi — robust, high-volume messaging for computational science workflows

USAGE: kiwi <subcommand> [options]

SUBCOMMANDS
  broker    run the message broker            [--addr HOST:PORT] [--wal PATH | --transient]
                                              [--shards N (0 = per-core)] [--delivery-batch N]
                                              [--route-cache N (0 = off)]
                                              [--net reactor|threads] [--event-batch N]
                                              [--outbox-cap BYTES]
                                              [--wal-segments N (0 = match shards)]
                                              [--wal-commit-interval-us N]
                                              [--page-out-threshold BYTES (0 = no paging)]
                                              [--page-in-batch N] [--publish-credit N (0 = off)]
                                              [--default-prefetch N (0 = unlimited)]
                                              [--stream-segment-bytes N]
                                              [--stream-retention-bytes N (0 = unbounded)]
                                              [--stream-retention-ms N (0 = unbounded)]
                                              [--stream-partitions N]
  worker    run a daemon (task consumer)      [--addr HOST:PORT] [--workers N]
                                              [--workflow-workers N (0 = match workers)]
                                              [--max-resident-processes N (0 = never park)]
  submit    launch a process and wait         --process TYPE [--inputs JSON] [--timeout-ms N]
  ctl       control live processes            <pause|play|kill|status> --pid PID [--reason R]
                                              (or --all: broadcast the intent to every process)
  status    broker status snapshot            [--addr HOST:PORT]

COMMON OPTIONS
  --config PATH       kiwi.json (default: ./kiwi.json if present)
  --heartbeat-ms N    heartbeat interval (0 = off)
  --artifacts DIR     AOT artifacts (default: artifacts)
  --checkpoints DIR   checkpoint store (default: .kiwi/checkpoints)

CONNECTION RESILIENCE (clients; outages are repaired transparently)
  --reconnect-max-retries N  give up after N failed re-dials (0 = no reconnect)
  --reconnect-backoff-ms N   base re-dial backoff (exponential, capped, jittered)

TASK LIFECYCLE (worker / submit; declared on the task queue)
  --max-delivery N           dead-letter a task after N attempts (0 = unlimited)
  --dead-letter-exchange EX  route dead tasks to EX (catch queue: <queue>.dlq)
  --max-length N             bound task-queue depth (0 = unbounded)
  --overflow POLICY          drop-head | reject-new when the queue is full
";

/// Entrypoint for `main`; returns the process exit code.
pub fn run(args: Args) -> i32 {
    match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let mut config = Config::load(args.opt("config").map(std::path::Path::new))?;
    if let Some(addr) = args.opt("addr") {
        config.broker_addr = addr.to_string();
    }
    if let Some(n) = args.opt_parse::<usize>("workers")? {
        config.workers = n;
    }
    if let Some(n) = args.opt_parse::<usize>("workflow-workers")? {
        config.workflow_workers = n;
    }
    if let Some(n) = args.opt_parse::<usize>("max-resident-processes")? {
        config.max_resident_processes = n;
    }
    if let Some(hb) = args.opt_parse::<u64>("heartbeat-ms")? {
        config.heartbeat_ms = hb;
    }
    if let Some(dir) = args.opt("artifacts") {
        config.artifacts_dir = dir.into();
    }
    if let Some(dir) = args.opt("checkpoints") {
        config.checkpoint_dir = dir.into();
    }
    if let Some(wal) = args.opt("wal") {
        config.wal_path = Some(wal.into());
    }
    if args.flag("transient") {
        config.wal_path = None;
    }
    if let Some(n) = args.opt_parse::<usize>("shards")? {
        config.shards = n;
    }
    if let Some(n) = args.opt_parse::<usize>("delivery-batch")? {
        config.delivery_batch = n.max(1);
    }
    if let Some(n) = args.opt_parse::<usize>("route-cache")? {
        config.route_cache_cap = n;
    }
    if let Some(n) = args.opt_parse::<u32>("max-delivery")? {
        config.max_delivery = (n > 0).then_some(n);
    }
    if let Some(ex) = args.opt("dead-letter-exchange") {
        config.dead_letter_exchange = (!ex.is_empty()).then(|| ex.to_string());
    }
    if let Some(n) = args.opt_parse::<usize>("max-length")? {
        config.max_length = (n > 0).then_some(n);
    }
    if let Some(p) = args.opt("overflow") {
        config.overflow = crate::broker::protocol::OverflowPolicy::parse(p)
            .map_err(|_| Error::Config(format!("--overflow: unknown policy '{p}'")))?;
    }
    if let Some(n) = args.opt_parse::<u32>("reconnect-max-retries")? {
        config.reconnect_max_retries = n;
    }
    if let Some(n) = args.opt_parse::<u64>("reconnect-backoff-ms")? {
        config.reconnect_backoff_ms = n;
    }
    if let Some(m) = args.opt("net") {
        if m != "reactor" && m != "threads" {
            return Err(Error::Config(format!("--net: unknown mode '{m}'")));
        }
        config.net = m.to_string();
    }
    if let Some(n) = args.opt_parse::<usize>("event-batch")? {
        config.event_batch = n.max(1);
    }
    if let Some(n) = args.opt_parse::<usize>("outbox-cap")? {
        config.outbox_cap = n.max(1);
    }
    if let Some(n) = args.opt_parse::<usize>("wal-segments")? {
        config.wal_segments = n;
    }
    if let Some(n) = args.opt_parse::<u64>("wal-commit-interval-us")? {
        config.wal_commit_interval_us = n;
    }
    if let Some(n) = args.opt_parse::<usize>("page-out-threshold")? {
        config.page_out_threshold = n;
    }
    if let Some(n) = args.opt_parse::<usize>("page-in-batch")? {
        config.page_in_batch = n.max(1);
    }
    if let Some(n) = args.opt_parse::<u32>("publish-credit")? {
        config.publish_credit = n;
    }
    if let Some(n) = args.opt_parse::<u32>("default-prefetch")? {
        config.default_prefetch = n;
    }
    if let Some(n) = args.opt_parse::<u64>("stream-segment-bytes")? {
        config.stream_segment_bytes = n.max(1);
    }
    if let Some(n) = args.opt_parse::<u64>("stream-retention-bytes")? {
        config.stream_retention_bytes = n;
    }
    if let Some(n) = args.opt_parse::<u64>("stream-retention-ms")? {
        config.stream_retention_ms = n;
    }
    if let Some(n) = args.opt_parse::<u32>("stream-partitions")? {
        config.stream_default_partitions = n.max(1);
    }
    Ok(config)
}

fn connect_communicator(config: &Config) -> Result<Arc<dyn Communicator>> {
    // Factory-connected: workers and submitters ride out broker restarts
    // (re-dial with backoff + topology revival) instead of dying with the
    // first link error.
    let comm = RmqCommunicator::connect_tcp(
        config.broker_addr.clone(),
        RmqConfig {
            heartbeat_ms: config.heartbeat_ms,
            request_timeout: config.request_timeout,
            task_max_delivery: config.max_delivery,
            task_dead_letter_exchange: config.dead_letter_exchange.clone(),
            task_max_length: config.max_length,
            task_overflow: config.overflow,
            reconnect_max_retries: config.reconnect_max_retries,
            reconnect_backoff_ms: config.reconnect_backoff_ms,
            ..Default::default()
        },
    )?;
    Ok(Arc::new(comm))
}

fn build_registry(config: &Config) -> Result<ProcessRegistry> {
    let registry = ProcessRegistry::new();
    let engine = Arc::new(Engine::load(&config.artifacts_dir)?);
    register_payload_processes(&registry, engine);
    Ok(registry)
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("broker") => cmd_broker(args),
        Some("worker") => cmd_worker(args),
        Some("submit") => cmd_submit(args),
        Some("ctl") => cmd_ctl(args),
        Some("status") => cmd_status(args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(Error::Config(format!("unknown subcommand '{other}'\n{USAGE}"))),
    }
}

fn cmd_broker(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let broker_config = config.broker_config();
    let broker = match &config.wal_path {
        Some(path) => {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let segments = config.wal_segments_resolved();
            let (wal, recovered) = SegmentedWal::open(
                path,
                segments,
                config.sync_policy,
                Duration::from_micros(config.wal_commit_interval_us),
            )?;
            let n = recovered.message_count();
            if n > 0 {
                println!("recovered {n} durable message(s) from {path:?} ({segments} segments)");
            }
            BrokerHandle::with_backend(Arc::new(wal), recovered, broker_config)
        }
        None => BrokerHandle::with_config(
            Box::new(crate::broker::persistence::NoopPersister),
            RecoveredState::default(),
            broker_config,
        ),
    };
    let server = BrokerServer::start_with(broker, &config.broker_addr, config.net_options())?;
    println!(
        "kiwi broker listening on {} ({:?} front-end, {} shards, delivery batch {}, route cache {})",
        server.addr(),
        server.net_mode(),
        broker_config.shards,
        broker_config.delivery_batch,
        broker_config.route_cache_cap
    );
    // Run until killed; the heartbeat monitor and sessions do the work.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_worker(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let comm = connect_communicator(&config)?;
    let registry = build_registry(&config)?;
    let store = Arc::new(FileCheckpointStore::open(&config.checkpoint_dir)?);
    let daemon_config = config.daemon_config();
    let scheduler_workers = daemon_config.workers;
    let daemon = Daemon::start(Arc::clone(&comm), store, registry, daemon_config)?;
    // Pick interrupted work back up: every non-terminal checkpoint left by
    // a previous daemon is re-enqueued through the task queue.
    match daemon.resume_stored() {
        Ok(0) => {}
        Ok(n) => println!("resuming {n} checkpointed process(es)"),
        Err(e) => eprintln!("warning: checkpoint resume scan failed: {e}"),
    }
    println!(
        "kiwi worker: {} scheduler threads (max resident {}) on queue '{}' via {}",
        scheduler_workers, config.max_resident_processes, config.task_queue, config.broker_addr
    );
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_submit(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let process = args
        .opt("process")
        .ok_or_else(|| Error::Config("submit needs --process TYPE".into()))?;
    let inputs = match args.opt("inputs") {
        Some(text) => json::from_str(text)?,
        None => Value::Null,
    };
    let timeout =
        Duration::from_millis(args.opt_parse::<u64>("timeout-ms")?.unwrap_or(3_600_000));
    let comm = connect_communicator(&config)?;
    let launcher = RemoteLauncher::with_queue(Arc::clone(&comm), &config.task_queue);
    let (pid, fut) = launcher.launch(process, inputs)?;
    println!("launched {process} as {pid}");
    let record = fut.wait(timeout)?;
    println!("{}", json::to_string_pretty(&record));
    Ok(())
}

fn cmd_ctl(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let intent = args
        .positional
        .first()
        .ok_or_else(|| Error::Config("ctl needs pause|play|kill|status".into()))?
        .clone();
    let comm = connect_communicator(&config)?;
    let ctl = ProcessController::new(comm).with_timeout(config.request_timeout);
    if args.flag("all") {
        // Campaign-wide sweep: one `control.all.<intent>` broadcast that
        // every scheduler applies to all of its resident processes.
        if !matches!(intent.as_str(), "pause" | "play" | "kill") {
            return Err(Error::Config(format!(
                "ctl --all supports pause|play|kill, not '{intent}'"
            )));
        }
        ctl.broadcast_intent(&intent)?;
        println!("broadcast {intent} to all processes");
        return Ok(());
    }
    let pid =
        args.opt("pid").ok_or_else(|| Error::Config("ctl needs --pid PID (or --all)".into()))?;
    match intent.as_str() {
        "pause" => println!("paused: {}", ctl.pause(pid)?),
        "play" => println!("resumed: {}", ctl.play(pid)?),
        "kill" => {
            println!("killed: {}", ctl.kill(pid, args.opt("reason").unwrap_or("kiwi ctl"))?)
        }
        "status" => println!("{}", json::to_string_pretty(&ctl.status(pid)?)),
        other => return Err(Error::Config(format!("unknown intent '{other}'"))),
    }
    Ok(())
}

fn cmd_status(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let link = connect_tcp(&config.broker_addr as &str)?;
    let conn = Connection::open(
        Arc::new(link),
        ConnectionConfig { heartbeat_ms: 0, ..Default::default() },
    )?;
    let status = conn.request(&ClientRequest::Status)?;
    println!("{}", json::to_string_pretty(&status));
    conn.close();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(parse("kiwi help")), 0);
        assert_eq!(run(parse("kiwi")), 0);
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert_eq!(run(parse("kiwi frobnicate")), 1);
    }

    #[test]
    fn submit_requires_process() {
        // Fails on the missing option before trying to connect.
        let err = dispatch(&parse("kiwi submit")).unwrap_err();
        assert!(err.to_string().contains("--process"));
    }

    #[test]
    fn ctl_requires_intent_and_pid() {
        let err = dispatch(&parse("kiwi ctl")).unwrap_err();
        assert!(err.to_string().contains("pause|play|kill|status"));
        let err = dispatch(&parse("kiwi ctl pause")).unwrap_err();
        assert!(err.to_string().contains("--pid"));
    }

    #[test]
    fn config_overrides_from_args() {
        let config = load_config(&parse(
            "kiwi worker --addr 9.9.9.9:9 --workers 3 --workflow-workers 2 \
             --max-resident-processes 50000 --heartbeat-ms 250 --transient \
             --shards 2 --delivery-batch 32 --route-cache 0 \
             --max-delivery 4 --dead-letter-exchange kiwi.dlx --max-length 100 \
             --overflow reject-new --net threads --event-batch 64 --outbox-cap 4096 \
             --page-out-threshold 1048576 --page-in-batch 8 --publish-credit 128 \
             --default-prefetch 16 --stream-segment-bytes 2097152 \
             --stream-retention-bytes 16777216 --stream-retention-ms 30000 \
             --stream-partitions 8",
        ))
        .unwrap();
        assert_eq!(config.broker_addr, "9.9.9.9:9");
        assert_eq!(config.workers, 3);
        assert_eq!(config.workflow_workers, 2);
        assert_eq!(config.max_resident_processes, 50_000);
        assert_eq!(config.daemon_config().workers, 2);
        assert_eq!(config.heartbeat_ms, 250);
        assert!(config.wal_path.is_none());
        assert_eq!(config.shards, 2);
        assert_eq!(config.delivery_batch, 32);
        assert_eq!(config.route_cache_cap, 0);
        assert_eq!(config.max_delivery, Some(4));
        assert_eq!(config.dead_letter_exchange.as_deref(), Some("kiwi.dlx"));
        assert_eq!(config.max_length, Some(100));
        assert_eq!(config.overflow, crate::broker::protocol::OverflowPolicy::RejectNew);
        assert_eq!(config.net, "threads");
        assert_eq!(config.event_batch, 64);
        assert_eq!(config.outbox_cap, 4096);
        assert_eq!(config.page_out_threshold, 1_048_576);
        assert_eq!(config.page_in_batch, 8);
        assert_eq!(config.publish_credit, 128);
        assert_eq!(config.default_prefetch, 16);
        assert_eq!(config.stream_segment_bytes, 2_097_152);
        assert_eq!(config.stream_retention_bytes, 16_777_216);
        assert_eq!(config.stream_retention_ms, 30_000);
        assert_eq!(config.stream_default_partitions, 8);
    }

    #[test]
    fn bad_net_mode_is_config_error() {
        let err = load_config(&parse("kiwi broker --net uring")).unwrap_err();
        assert!(err.to_string().contains("--net"));
    }

    #[test]
    fn bad_overflow_policy_is_config_error() {
        let err = load_config(&parse("kiwi worker --overflow sideways")).unwrap_err();
        assert!(err.to_string().contains("overflow"));
    }
}
