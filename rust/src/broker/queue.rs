//! A single message queue: priority-laned ready list, unacked in-flight
//! tracking, consumer round-robin with prefetch accounting, TTL expiry —
//! or, for `stream` queues, an append-only log with cursor-based consumer
//! groups and replay (see [`StreamState`]).
//!
//! The work-queue model is pure data structure — no locks, no I/O — which
//! is what makes it property-testable. Stream queues own their
//! [`StreamStore`] (segment-file appends/reads under the shard lock, a
//! leaf I/O like WAL appends — never re-entering another lock). The
//! [`super::shard`] module wraps a shard lock around a subset of `Queue`s;
//! [`super::core`] composes the shards.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use crate::broker::persistence::{BodyLocator, RecoveredStream, StreamStore};
use crate::broker::protocol::{EncodedProps, OverflowPolicy, QueueOptions};
use crate::wire::{Bytes, Value};

/// Number of priority lanes (priorities 0–9).
pub const PRIORITY_LANES: usize = 10;

/// A message held by a queue. Every field that can be large is behind a
/// refcount (`Arc<str>` names, [`Bytes`] body, [`EncodedProps`]), so the
/// per-delivery / per-fanout-copy `clone()` is a handful of refcount bumps
/// — the payload is encoded once at the publisher and never duplicated.
#[derive(Clone, Debug)]
pub struct QueuedMessage {
    /// Broker-wide unique id (also the WAL record id for durable queues).
    pub msg_id: u64,
    pub exchange: Arc<str>,
    pub routing_key: Arc<str>,
    /// The publisher's encoded body — opaque to the broker.
    pub body: Bytes,
    pub props: EncodedProps,
    /// Instant after which the message is expired (from per-message or
    /// per-queue TTL).
    pub deadline: Option<Instant>,
    /// True once the message has been delivered at least once before.
    pub redelivered: bool,
    /// Completed delivery attempts (incremented when the message is
    /// assigned to a consumer; decremented back when the send never
    /// reached the wire). Checked against `max_delivery` at requeue time
    /// and preserved across WAL recovery.
    pub delivery_count: u32,
    /// Where the WAL already holds this body byte-identically (durable
    /// queues only, minted when the publish record is appended). Lets the
    /// pager drop `body` without writing anything.
    pub stored: Option<BodyLocator>,
    /// Set while the body is evicted from memory: the locator to re-read
    /// it from. `body` is empty whenever this is `Some`; assignment never
    /// hands out a paged message.
    pub paged: Option<BodyLocator>,
}

impl QueuedMessage {
    fn lane(&self) -> usize {
        (self.props.priority as usize).min(PRIORITY_LANES - 1)
    }

    fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// Why a message left its queue without being acked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeadReason {
    /// Consumer refused it with `requeue = false`.
    Rejected,
    /// Requeue requested, but the message hit the `max_delivery` cap.
    MaxDelivery,
    /// TTL deadline passed.
    Expired,
    /// Evicted (drop-head) or refused (reject-new) by `max_length`.
    Overflow,
}

impl DeadReason {
    /// Stable wire/WAL name (used in `x-death` metadata and retire
    /// records).
    pub fn as_str(self) -> &'static str {
        match self {
            DeadReason::Rejected => "rejected",
            DeadReason::MaxDelivery => "max-delivery",
            DeadReason::Expired => "expired",
            DeadReason::Overflow => "overflow",
        }
    }
}

/// A message that left its queue dead, before dead-letter routing.
#[derive(Clone, Debug)]
pub struct DeadLettered {
    pub reason: DeadReason,
    pub message: QueuedMessage,
}

/// A dead message annotated with everything the core needs to route it to
/// the source queue's DLX (or retire it) *after* all shard locks are
/// released — the dead-letter pipeline never publishes from inside a shard
/// lock, which is what keeps it deadlock-free.
#[derive(Clone, Debug)]
pub struct PendingDead {
    /// Queue the message died in.
    pub source: Arc<str>,
    pub dead_letter_exchange: Option<String>,
    pub dead_letter_routing_key: Option<String>,
    /// Source queue durability — governs WAL retire-with-reason records.
    pub durable: bool,
    pub reason: DeadReason,
    pub message: QueuedMessage,
}

/// Result of [`Queue::publish`].
#[must_use]
pub struct PublishOutcome {
    /// False only when a `reject-new` overflow refused the message (it is
    /// then in `dead`, not in the queue).
    pub accepted: bool,
    /// Messages the publish displaced (overflow evictions, or the refused
    /// message itself) — the core dead-letters or retires them.
    pub dead: Vec<DeadLettered>,
}

/// Result of [`Queue::nack`].
#[must_use]
pub enum NackOutcome {
    /// Unknown delivery tag (double-nack is idempotent).
    Unknown,
    /// Returned to the front of its priority lane.
    Requeued { msg_id: u64, delivery_count: u32 },
    /// Left the queue: rejected outright, or requeue refused by the
    /// `max_delivery` cap.
    Dead(DeadLettered),
}

/// Result of [`Queue::drop_connection`].
pub struct DropOutcome {
    /// Delivery tags that died with the connection (caller prunes its
    /// delivery index; requeued messages get fresh tags on redelivery).
    pub dead_tags: Vec<u64>,
    /// Messages that could not be requeued (over the `max_delivery` cap).
    pub dead: Vec<DeadLettered>,
    /// `(msg_id, delivery_count)` of requeued messages — WAL requeue
    /// records for durable queues, so attempt counts survive recovery.
    pub requeued: Vec<(u64, u32)>,
}

/// A consumer attached to a queue.
#[derive(Clone, Debug)]
pub struct Consumer {
    pub consumer_tag: String,
    /// Owning connection (used to requeue on connection death).
    pub connection: u64,
    /// Max unacked deliveries outstanding; 0 = unlimited.
    pub prefetch: u32,
    /// Current unacked deliveries outstanding.
    pub in_flight: u32,
}

impl Consumer {
    fn has_capacity(&self) -> bool {
        self.prefetch == 0 || self.in_flight < self.prefetch
    }
}

/// A message handed to a consumer, not yet acknowledged.
#[derive(Clone, Debug)]
pub struct InFlight {
    pub message: QueuedMessage,
    pub consumer_tag: String,
    pub connection: u64,
}

/// A delivery decision produced by the queue (the core turns these into
/// wire messages).
#[derive(Clone, Debug)]
pub struct Assignment {
    pub consumer_tag: String,
    pub connection: u64,
    pub delivery_tag: u64,
    pub message: QueuedMessage,
    /// Stream queues only: the entry's log offset (rides the wire so the
    /// consumer can commit it). `None` for work-queue deliveries.
    pub offset: Option<u64>,
}

/// How many recently-touched entry bodies a stream keeps resident in
/// memory. Publishes keep the hot tail warm; replay readers page older
/// bodies back in through this same bounded window. Everything else lives
/// only in the segment files — this is what keeps broker RSS flat under
/// 100 replaying readers.
const STREAM_RESIDENT_WINDOW: usize = 64;

/// One entry of a stream's in-memory index. The body is behind the same
/// refcounted [`Bytes`] as work-queue messages (delivery to N groups is N
/// refcount bumps), and is dropped to empty once the entry falls out of
/// the resident window — `locator` then points at the byte-identical copy
/// in the segment file. `locator == None` means the stream has no store
/// (memory-only); such bodies are never evicted.
#[derive(Clone, Debug)]
struct StreamEntry {
    offset: u64,
    msg_id: u64,
    exchange: Arc<str>,
    routing_key: Arc<str>,
    body: Bytes,
    props: EncodedProps,
    locator: Option<BodyLocator>,
}

/// A stream delivery awaiting ack, tracked per delivery tag.
#[derive(Clone, Debug)]
struct StreamInFlight {
    offset: u64,
    consumer_tag: String,
    connection: u64,
}

/// One consumer group's cursor over the log. Offsets below `committed`
/// are consumed; `cursor` is the next never-delivered offset; the gap in
/// between is in flight (`unacked`), acked out of order (`acked`) or
/// awaiting redelivery (`redeliver`). Members share the group's work by
/// partition: offset `o` always goes to member `(o % partitions) % len`.
struct StreamGroup {
    committed: u64,
    cursor: u64,
    /// Offsets acked ahead of `committed` (out-of-order acks); drained
    /// into `committed` as the contiguous prefix closes.
    acked: BTreeSet<u64>,
    /// Offsets whose delivery failed (nack-requeue, consumer death) —
    /// served before `cursor`, smallest first.
    redeliver: BTreeSet<u64>,
    unacked: HashMap<u64, StreamInFlight>,
    members: Vec<Consumer>,
}

impl StreamGroup {
    fn new(start: u64) -> Self {
        StreamGroup {
            committed: start,
            cursor: start,
            acked: BTreeSet::new(),
            redeliver: BTreeSet::new(),
            unacked: HashMap::new(),
            members: Vec::new(),
        }
    }

    /// Reposition the group at `offset` (replay or skip-ahead). In-flight
    /// deliveries stay ackable; per-offset state below/above the new
    /// position is meaningless and cleared.
    fn seek(&mut self, offset: u64) {
        self.committed = offset;
        self.cursor = offset;
        self.acked.clear();
        self.redeliver.clear();
    }
}

/// The log state of a `stream` queue: a contiguous window of entries
/// (`entries[i].offset == base_offset + i` — retention truncates the
/// front, publish appends at the back), the consumer groups reading it,
/// and the backing [`StreamStore`].
pub struct StreamState {
    entries: VecDeque<StreamEntry>,
    /// Offset of `entries[0]` (== `next_offset` when empty).
    base_offset: u64,
    /// Offset the next publish takes.
    next_offset: u64,
    partitions: u32,
    /// `BTreeMap` for deterministic group iteration order in assignment.
    groups: BTreeMap<String, StreamGroup>,
    /// Delivery tag → owning group name (acks don't carry the group).
    tag_index: HashMap<u64, String>,
    /// Offsets whose body is currently resident, oldest-touched first —
    /// the eviction ring bounding memory to [`STREAM_RESIDENT_WINDOW`].
    resident: VecDeque<u64>,
    resident_bytes: u64,
    store: Option<StreamStore>,
}

impl StreamState {
    fn new(partitions: u32) -> Self {
        StreamState {
            entries: VecDeque::new(),
            base_offset: 0,
            next_offset: 0,
            partitions: partitions.max(1),
            groups: BTreeMap::new(),
            tag_index: HashMap::new(),
            resident: VecDeque::new(),
            resident_bytes: 0,
            store: None,
        }
    }

    /// Append one entry to the log. Store failures degrade the entry to
    /// memory-only (locator `None`, body pinned resident) — an entry is
    /// never lost to an I/O error, it just can't be evicted or replayed
    /// across restart.
    fn publish(&mut self, msg: QueuedMessage) {
        let offset = self.next_offset;
        self.next_offset += 1;
        let locator = match self.store.as_mut() {
            Some(store) => match store.append(offset, &msg) {
                Ok(loc) => Some(loc),
                Err(e) => {
                    log::error!("stream: append of offset {offset} failed, entry pinned in memory: {e}");
                    None
                }
            },
            None => None,
        };
        self.resident_bytes += msg.body.len() as u64;
        if locator.is_some() {
            self.resident.push_back(offset);
        }
        self.entries.push_back(StreamEntry {
            offset,
            msg_id: msg.msg_id,
            exchange: msg.exchange,
            routing_key: msg.routing_key,
            body: msg.body,
            props: msg.props,
            locator,
        });
        self.evict_overflow();
    }

    /// Shrink the resident window back to its bound by dropping the
    /// oldest-touched bodies (a refcount decrement — in-flight deliveries
    /// keep their clones alive).
    fn evict_overflow(&mut self) {
        while self.resident.len() > STREAM_RESIDENT_WINDOW {
            let off = self.resident.pop_front().unwrap();
            if off < self.base_offset {
                continue;
            }
            let i = (off - self.base_offset) as usize;
            if let Some(e) = self.entries.get_mut(i) {
                if e.locator.is_some() && !e.body.is_empty() {
                    self.resident_bytes = self.resident_bytes.saturating_sub(e.body.len() as u64);
                    e.body = Bytes::new();
                }
            }
        }
    }

    /// Make the entry at `offset` deliverable: page its body back in from
    /// the store if it was evicted. `false` means it cannot be delivered
    /// right now (truncated away, or the disk read failed — the group
    /// stalls rather than receiving an empty body).
    fn ensure_resident(&mut self, offset: u64) -> bool {
        if offset < self.base_offset {
            return false;
        }
        let i = (offset - self.base_offset) as usize;
        let Some(entry) = self.entries.get(i) else { return false };
        if !entry.body.is_empty() || entry.locator.is_none() {
            return true;
        }
        let loc = entry.locator.unwrap();
        if loc.len == 0 {
            return true;
        }
        let Some(store) = self.store.as_mut() else { return false };
        match store.read_body(loc) {
            Ok(body) => {
                self.resident_bytes += body.len() as u64;
                self.entries[i].body = body;
                self.resident.push_back(offset);
                self.evict_overflow();
                true
            }
            Err(e) => {
                log::error!("stream: body read at offset {offset} failed: {e}");
                false
            }
        }
    }

    /// Assign ready offsets to group members, partition-ordered: offset
    /// `o` goes to member `(o % partitions) % members`, redeliveries
    /// first. When the partition owner is at capacity (or its connection
    /// is paused) the whole group waits — handing the offset to another
    /// member would break per-partition ordering.
    fn assign(
        &mut self,
        limit: usize,
        next_tag: &mut impl FnMut() -> u64,
        conn_ready: &impl Fn(u64) -> bool,
    ) -> Vec<Assignment> {
        enum Pick {
            Deliver(u64, bool, usize),
            /// Offset fell behind retention — drop it and retry.
            Skip(u64, bool),
            Stall,
            Drained,
        }
        let mut out = Vec::new();
        let gnames: Vec<String> = self.groups.keys().cloned().collect();
        'groups: for gname in gnames {
            loop {
                if out.len() >= limit {
                    break 'groups;
                }
                let pick = {
                    let g = self.groups.get(&gname).unwrap();
                    if g.members.is_empty() {
                        Pick::Drained
                    } else {
                        let next = match g.redeliver.iter().next().copied() {
                            Some(o) => Some((o, true)),
                            None if g.cursor < self.next_offset => Some((g.cursor, false)),
                            None => None,
                        };
                        match next {
                            None => Pick::Drained,
                            Some((offset, redelivered)) => {
                                if offset < self.base_offset {
                                    Pick::Skip(offset, redelivered)
                                } else {
                                    let part =
                                        (offset % u64::from(self.partitions)) as usize;
                                    let idx = part % g.members.len();
                                    let m = &g.members[idx];
                                    if m.has_capacity() && conn_ready(m.connection) {
                                        Pick::Deliver(offset, redelivered, idx)
                                    } else {
                                        Pick::Stall
                                    }
                                }
                            }
                        }
                    }
                };
                match pick {
                    Pick::Drained => break,
                    Pick::Stall => break,
                    Pick::Skip(offset, redelivered) => {
                        let g = self.groups.get_mut(&gname).unwrap();
                        if redelivered {
                            g.redeliver.remove(&offset);
                        } else {
                            g.cursor = self.base_offset;
                            g.committed = g.committed.max(self.base_offset);
                        }
                        continue;
                    }
                    Pick::Deliver(offset, redelivered, member_idx) => {
                        if !self.ensure_resident(offset) {
                            break;
                        }
                        let e = &self.entries[(offset - self.base_offset) as usize];
                        let (msg_id, exchange, routing_key, body, props) = (
                            e.msg_id,
                            Arc::clone(&e.exchange),
                            Arc::clone(&e.routing_key),
                            e.body.clone(),
                            e.props.clone(),
                        );
                        let tag = next_tag();
                        let g = self.groups.get_mut(&gname).unwrap();
                        let m = &mut g.members[member_idx];
                        m.in_flight += 1;
                        let (consumer_tag, connection) =
                            (m.consumer_tag.clone(), m.connection);
                        // A replay below the committed watermark is by
                        // definition a redelivery to this group.
                        let was_consumed = offset < g.committed;
                        if redelivered {
                            g.redeliver.remove(&offset);
                        } else {
                            g.cursor = offset + 1;
                        }
                        g.unacked.insert(
                            tag,
                            StreamInFlight {
                                offset,
                                consumer_tag: consumer_tag.clone(),
                                connection,
                            },
                        );
                        self.tag_index.insert(tag, gname.clone());
                        out.push(Assignment {
                            consumer_tag,
                            connection,
                            delivery_tag: tag,
                            message: QueuedMessage {
                                msg_id,
                                exchange,
                                routing_key,
                                body,
                                props,
                                deadline: None,
                                redelivered: redelivered || was_consumed,
                                delivery_count: if redelivered { 2 } else { 1 },
                                stored: None,
                                paged: None,
                            },
                            offset: Some(offset),
                        });
                    }
                }
            }
        }
        out
    }

    /// Ack a stream delivery: advances the group's committed watermark
    /// over the now-contiguous acked prefix (out-of-order acks park in
    /// `acked` until the gap closes). Returns the entry's msg id.
    fn ack(&mut self, tag: u64) -> Option<u64> {
        let gname = self.tag_index.remove(&tag)?;
        let (offset, advanced_to) = {
            let g = self.groups.get_mut(&gname)?;
            let inflight = g.unacked.remove(&tag)?;
            if let Some(m) =
                g.members.iter_mut().find(|m| m.consumer_tag == inflight.consumer_tag)
            {
                m.in_flight = m.in_flight.saturating_sub(1);
            }
            let mut advanced = None;
            // Acks at already-committed offsets (post-seek replay) must
            // not park in `acked` — they would never drain.
            if inflight.offset >= g.committed {
                g.acked.insert(inflight.offset);
                let before = g.committed;
                while g.acked.remove(&g.committed) {
                    g.committed += 1;
                }
                if g.committed != before {
                    advanced = Some(g.committed);
                }
            }
            (inflight.offset, advanced)
        };
        if let Some(committed) = advanced_to {
            if let Some(store) = self.store.as_mut() {
                if let Err(e) = store.record_commit(&gname, committed) {
                    log::error!("stream: commit record for group {gname:?} failed: {e}");
                }
            }
        }
        Some(self.msg_id_at(offset))
    }

    /// Return an in-flight offset to its group's redelivery set (nack
    /// with requeue, failed send, consumer death). Returns the msg id.
    fn requeue(&mut self, tag: u64) -> Option<u64> {
        let gname = self.tag_index.remove(&tag)?;
        let offset = {
            let g = self.groups.get_mut(&gname)?;
            let inflight = g.unacked.remove(&tag)?;
            if let Some(m) =
                g.members.iter_mut().find(|m| m.consumer_tag == inflight.consumer_tag)
            {
                m.in_flight = m.in_flight.saturating_sub(1);
            }
            if inflight.offset >= g.committed {
                g.redeliver.insert(inflight.offset);
            }
            inflight.offset
        };
        Some(self.msg_id_at(offset))
    }

    fn msg_id_at(&self, offset: u64) -> u64 {
        if offset < self.base_offset {
            return 0;
        }
        self.entries.get((offset - self.base_offset) as usize).map_or(0, |e| e.msg_id)
    }

    /// Remove a connection's members from every group and return its dead
    /// delivery tags plus how many offsets went back for redelivery.
    fn drop_connection(&mut self, connection: u64) -> (Vec<u64>, u64) {
        let mut dead_tags = Vec::new();
        let mut requeued = 0u64;
        for g in self.groups.values_mut() {
            let tags: Vec<u64> = g
                .unacked
                .iter()
                .filter(|(_, f)| f.connection == connection)
                .map(|(t, _)| *t)
                .collect();
            for t in tags {
                if let Some(f) = g.unacked.remove(&t) {
                    if f.offset >= g.committed {
                        g.redeliver.insert(f.offset);
                        requeued += 1;
                    }
                }
                dead_tags.push(t);
            }
            // Surviving members re-cover the dead one's partitions on the
            // next assignment round — `(o % partitions) % members` shifts
            // with the member count; no explicit rebalance step needed.
            g.members.retain(|m| m.connection != connection);
        }
        for t in &dead_tags {
            self.tag_index.remove(t);
        }
        (dead_tags, requeued)
    }

    /// Drop every entry below `new_base` (retention/purge). Group cursors
    /// and per-offset state clamp forward; in-flight deliveries at
    /// truncated offsets stay ackable (their body clone is alive).
    fn truncate_to(&mut self, new_base: u64) {
        while self.base_offset < new_base {
            match self.entries.pop_front() {
                Some(e) => {
                    self.resident_bytes =
                        self.resident_bytes.saturating_sub(e.body.len() as u64);
                    self.base_offset += 1;
                }
                None => {
                    self.base_offset = new_base;
                    break;
                }
            }
        }
        let base = self.base_offset;
        self.resident.retain(|o| *o >= base);
        for g in self.groups.values_mut() {
            g.committed = g.committed.max(base);
            g.cursor = g.cursor.max(g.committed);
            g.acked = g.acked.split_off(&base);
            g.redeliver = g.redeliver.split_off(&base);
        }
    }
}

/// The queue itself.
pub struct Queue {
    /// Interned name handle (shared with the router's interner and the
    /// shard map key — cloning it anywhere is a refcount bump).
    pub name: Arc<str>,
    pub options: QueueOptions,
    /// Declaring connection (for `exclusive`).
    pub owner: Option<u64>,
    /// Ready messages by priority lane; FIFO within a lane.
    ready: [VecDeque<QueuedMessage>; PRIORITY_LANES],
    ready_count: usize,
    /// Ready messages carrying a TTL deadline. When zero, the periodic
    /// expiry sweep skips this queue without scanning it.
    ttl_ready: usize,
    /// Lower bound on the earliest deadline among ready TTL'd messages
    /// (exact after a full sweep, conservative otherwise — popping a
    /// message never raises it). `Some` iff `ttl_ready > 0`.
    earliest_deadline: Option<Instant>,
    /// Body bytes of ready messages currently resident in memory.
    resident_bytes: u64,
    /// Body bytes of ready messages evicted to the WAL / spill file.
    paged_bytes: u64,
    /// Ready messages whose body is evicted (subset of `ready_count`).
    paged_count: usize,
    /// Monotonic page-out / page-in event counts (for metrics).
    pub page_outs: u64,
    pub page_ins: u64,
    /// Delivered, awaiting ack, keyed by delivery tag.
    unacked: HashMap<u64, InFlight>,
    consumers: Vec<Consumer>,
    /// Round-robin cursor over `consumers`.
    rr_cursor: usize,
    /// Statistics (monotonic).
    pub published: u64,
    pub delivered: u64,
    pub acked: u64,
    pub requeued: u64,
    pub expired: u64,
    pub dropped_overflow: u64,
    /// Messages that left this queue dead (rejected / max-delivery /
    /// overflow; expiries are counted in `expired`).
    pub dead_lettered: u64,
    /// Expired messages encountered during assignment, buffered for the
    /// core to dead-letter / retire (see `drain_expired`).
    expired_buf: Vec<QueuedMessage>,
    /// `Some` iff `options.stream`: the append-only log replacing the
    /// ready/unacked machinery above (which stays empty for streams).
    stream: Option<StreamState>,
}

impl Queue {
    pub fn new(name: impl Into<Arc<str>>, options: QueueOptions, owner: Option<u64>) -> Self {
        let stream = options.stream.then(|| StreamState::new(options.partitions));
        Queue {
            name: name.into(),
            options,
            owner,
            ready: Default::default(),
            ready_count: 0,
            ttl_ready: 0,
            earliest_deadline: None,
            resident_bytes: 0,
            paged_bytes: 0,
            paged_count: 0,
            page_outs: 0,
            page_ins: 0,
            unacked: HashMap::new(),
            consumers: Vec::new(),
            rr_cursor: 0,
            published: 0,
            delivered: 0,
            acked: 0,
            requeued: 0,
            expired: 0,
            dropped_overflow: 0,
            dead_lettered: 0,
            expired_buf: Vec::new(),
            stream,
        }
    }

    pub fn ready_len(&self) -> usize {
        self.ready_count
    }

    pub fn unacked_len(&self) -> usize {
        match &self.stream {
            Some(s) => s.groups.values().map(|g| g.unacked.len()).sum(),
            None => self.unacked.len(),
        }
    }

    pub fn consumer_count(&self) -> usize {
        match &self.stream {
            Some(s) => s.groups.values().map(|g| g.members.len()).sum(),
            None => self.consumers.len(),
        }
    }

    pub fn has_consumer(&self, tag: &str) -> bool {
        match &self.stream {
            Some(s) => {
                s.groups.values().any(|g| g.members.iter().any(|c| c.consumer_tag == tag))
            }
            None => self.consumers.iter().any(|c| c.consumer_tag == tag),
        }
    }

    /// The attached consumers (the core uses this to notify owners when a
    /// queue is deleted out from under them). Work-queue consumers only —
    /// see [`Queue::all_consumers`] for a view that includes stream group
    /// members.
    pub fn consumers(&self) -> &[Consumer] {
        &self.consumers
    }

    /// Every attached consumer, including stream group members.
    pub fn all_consumers(&self) -> Vec<Consumer> {
        match &self.stream {
            Some(s) => s.groups.values().flat_map(|g| g.members.iter().cloned()).collect(),
            None => self.consumers.clone(),
        }
    }

    /// Enqueue a message. Applies the queue default TTL when the message
    /// has none and enforces `max_length` per the queue's overflow policy:
    /// `drop-head` evicts the oldest ready message(s), `reject-new`
    /// refuses the incoming one. Displaced messages come back in the
    /// outcome so the core can dead-letter (or retire) them — nothing is
    /// silently dropped here.
    pub fn publish(&mut self, mut msg: QueuedMessage, now: Instant) -> PublishOutcome {
        if let Some(s) = self.stream.as_mut() {
            // Streams are append-only: no TTL expiry, no max_length
            // overflow, no dead-lettering — entries leave only by whole-
            // segment retention. Every publish is accepted.
            msg.deadline = None;
            s.publish(msg);
            self.published += 1;
            return PublishOutcome { accepted: true, dead: Vec::new() };
        }
        if msg.deadline.is_none() {
            let ttl = msg.props.expiration_ms.or(self.options.default_ttl_ms);
            msg.deadline =
                ttl.map(|ms| now + std::time::Duration::from_millis(ms));
        }
        let mut dead = Vec::new();
        if let Some(max) = self.options.max_length {
            if self.ready_count >= max.max(1) {
                match self.options.overflow {
                    OverflowPolicy::DropHead => {
                        while self.ready_count >= max.max(1) {
                            if let Some(old) = self.pop_ready(now) {
                                self.dropped_overflow += 1;
                                self.dead_lettered += 1;
                                dead.push(DeadLettered {
                                    reason: DeadReason::Overflow,
                                    message: old,
                                });
                            } else {
                                break;
                            }
                        }
                    }
                    OverflowPolicy::RejectNew => {
                        self.dropped_overflow += 1;
                        self.dead_lettered += 1;
                        dead.push(DeadLettered { reason: DeadReason::Overflow, message: msg });
                        return PublishOutcome { accepted: false, dead };
                    }
                }
            }
        }
        self.track_in(&msg);
        let lane = msg.lane();
        self.ready[lane].push_back(msg);
        self.ready_count += 1;
        self.published += 1;
        PublishOutcome { accepted: true, dead }
    }

    /// Bookkeeping when a message enters a ready lane: maintains the
    /// earliest-deadline lower bound the sweep gates on, plus the
    /// resident/paged byte accounting the pager steers by.
    fn track_in(&mut self, msg: &QueuedMessage) {
        if let Some(d) = msg.deadline {
            self.ttl_ready += 1;
            self.earliest_deadline = Some(self.earliest_deadline.map_or(d, |e| e.min(d)));
        }
        match msg.paged {
            Some(loc) => {
                self.paged_bytes += u64::from(loc.len);
                self.paged_count += 1;
            }
            None => self.resident_bytes += msg.body.len() as u64,
        }
    }

    /// Bookkeeping when a message leaves a ready lane. The deadline bound
    /// is not recomputed (it may now be earlier than any live deadline — a
    /// sweep then scans needlessly but never skips wrongly); it resets
    /// exactly when no TTL'd message remains.
    fn track_out(&mut self, msg: &QueuedMessage) {
        if msg.deadline.is_some() {
            self.ttl_ready -= 1;
            if self.ttl_ready == 0 {
                self.earliest_deadline = None;
            }
        }
        match msg.paged {
            Some(loc) => {
                self.paged_bytes = self.paged_bytes.saturating_sub(u64::from(loc.len));
                self.paged_count = self.paged_count.saturating_sub(1);
            }
            None => {
                self.resident_bytes = self.resident_bytes.saturating_sub(msg.body.len() as u64);
            }
        }
    }

    /// Ready messages currently carrying a TTL deadline (sweep-skip
    /// bookkeeping, exposed for tests).
    pub fn ttl_pending(&self) -> usize {
        self.ttl_ready
    }

    /// Pop the highest-priority, oldest ready message, setting aside
    /// expired ones along the way (buffered in `expired_buf` for the core
    /// to dead-letter / retire).
    fn pop_ready(&mut self, now: Instant) -> Option<QueuedMessage> {
        for lane in (0..PRIORITY_LANES).rev() {
            while let Some(msg) = self.ready[lane].pop_front() {
                self.ready_count -= 1;
                self.track_out(&msg);
                if msg.expired(now) {
                    self.expired += 1;
                    self.expired_buf.push(msg);
                    continue;
                }
                return Some(msg);
            }
        }
        None
    }

    /// True when another delivery of `m` would exceed the queue's
    /// `max_delivery` cap — i.e. the message may no longer be requeued.
    fn over_delivery_cap(&self, m: &QueuedMessage) -> bool {
        self.options.max_delivery.is_some_and(|max| m.delivery_count >= max.max(1))
    }

    /// Register a consumer. Fails (returns false) if the tag is taken.
    /// Work queues only — stream readers attach through
    /// [`Queue::add_stream_member`] (the core rejects a plain `Consume`
    /// on a stream queue).
    pub fn add_consumer(&mut self, consumer: Consumer) -> bool {
        if self.stream.is_some() || self.has_consumer(&consumer.consumer_tag) {
            return false;
        }
        self.consumers.push(consumer);
        true
    }

    /// Attach a consumer to a stream group (created on first attach at
    /// the stream's tail). A `seek` offset repositions the group — only
    /// honored while the group has no other members, so one attach can't
    /// yank the cursor out from under live readers. Fails (returns false)
    /// on non-stream queues or a taken tag.
    pub fn add_stream_member(
        &mut self,
        group: &str,
        consumer: Consumer,
        seek: Option<u64>,
    ) -> bool {
        if self.has_consumer(&consumer.consumer_tag) {
            return false;
        }
        let Some(s) = self.stream.as_mut() else { return false };
        let next = s.next_offset;
        let g = s
            .groups
            .entry(group.to_string())
            .or_insert_with(|| StreamGroup::new(seek.unwrap_or(next)));
        if g.members.is_empty() {
            if let Some(o) = seek {
                g.seek(o);
            }
        }
        g.members.push(consumer);
        let committed = g.committed;
        if let Some(store) = s.store.as_mut() {
            // Persist the (possibly seeked) position so recovery resumes
            // the group from here.
            if let Err(e) = store.record_commit(group, committed) {
                log::error!("stream: commit record for group {group:?} failed: {e}");
            }
        }
        true
    }

    /// Remove a consumer by tag. Returns true if it existed. For streams,
    /// in-flight deliveries stay ackable (like work-queue cancel);
    /// connection death eventually redelivers anything left.
    pub fn remove_consumer(&mut self, tag: &str) -> bool {
        if let Some(s) = self.stream.as_mut() {
            let mut removed = false;
            for g in s.groups.values_mut() {
                let before = g.members.len();
                g.members.retain(|c| c.consumer_tag != tag);
                removed |= g.members.len() != before;
            }
            return removed;
        }
        let before = self.consumers.len();
        self.consumers.retain(|c| c.consumer_tag != tag);
        if self.rr_cursor >= self.consumers.len() {
            self.rr_cursor = 0;
        }
        self.consumers.len() != before
    }

    /// Remove a consumer only if it is owned by `connection`. Used by
    /// rollback paths so they cannot tear down a same-tag consumer that a
    /// different (live) connection registered in the meantime.
    pub fn remove_consumer_of(&mut self, tag: &str, connection: u64) -> bool {
        if let Some(s) = self.stream.as_mut() {
            let mut removed = false;
            for g in s.groups.values_mut() {
                let before = g.members.len();
                g.members.retain(|c| !(c.consumer_tag == tag && c.connection == connection));
                removed |= g.members.len() != before;
            }
            return removed;
        }
        let before = self.consumers.len();
        self.consumers.retain(|c| !(c.consumer_tag == tag && c.connection == connection));
        if self.rr_cursor >= self.consumers.len() {
            self.rr_cursor = 0;
        }
        self.consumers.len() != before
    }

    /// Drive delivery: assign ready messages to consumers with free
    /// prefetch capacity, round-robin. `next_tag` allocates delivery tags.
    ///
    /// This is the queue's core invariant enforcement point: a message is
    /// moved from `ready` to `unacked` *atomically* with the decision to
    /// hand it to exactly one consumer — the "no race conditions between
    /// multiple daemon processes" guarantee in the paper.
    pub fn assign(&mut self, now: Instant, next_tag: impl FnMut() -> u64) -> Vec<Assignment> {
        self.assign_up_to(now, usize::MAX, next_tag)
    }

    /// Like [`Queue::assign`] but hands out at most `limit` messages — the
    /// batched-dispatch entry point, bounding how long a shard lock is held
    /// per drain round.
    pub fn assign_up_to(
        &mut self,
        now: Instant,
        limit: usize,
        next_tag: impl FnMut() -> u64,
    ) -> Vec<Assignment> {
        self.assign_up_to_filtered(now, limit, next_tag, |_| true)
    }

    /// Like [`Queue::assign_up_to`] with a connection-readiness filter:
    /// consumers whose connection reports an over-cap outbox are skipped
    /// (their prefetch capacity is left untouched, and the messages stay
    /// ready) — per-connection output backpressure. A paused connection
    /// never stalls assignment to ready consumers on other connections.
    pub fn assign_up_to_filtered(
        &mut self,
        now: Instant,
        limit: usize,
        mut next_tag: impl FnMut() -> u64,
        conn_ready: impl Fn(u64) -> bool,
    ) -> Vec<Assignment> {
        if let Some(s) = self.stream.as_mut() {
            // Offset-based assignment: nothing is popped — each group
            // walks its own cursor over the shared log.
            let out = s.assign(limit, &mut next_tag, &conn_ready);
            self.delivered += out.len() as u64;
            return out;
        }
        let mut out = Vec::new();
        if self.consumers.is_empty() || limit == 0 {
            return out;
        }
        'outer: while self.ready_count > 0 && out.len() < limit {
            // Find the next consumer with capacity, starting at the cursor.
            let n = self.consumers.len();
            let mut found = None;
            for i in 0..n {
                let idx = (self.rr_cursor + i) % n;
                if self.consumers[idx].has_capacity()
                    && conn_ready(self.consumers[idx].connection)
                {
                    found = Some(idx);
                    break;
                }
            }
            let Some(idx) = found else { break 'outer };
            let Some(mut msg) = self.pop_ready(now) else { break 'outer };
            if msg.paged.is_some() {
                // The head has drained into the paged tail: the body is on
                // disk, so delivery must wait for the dispatch pump's
                // page-in pass (which restores bodies off the shard lock).
                // Put it back and stop — never hand out an empty body.
                self.track_in(&msg);
                let lane = msg.lane();
                self.ready[lane].push_front(msg);
                self.ready_count += 1;
                break 'outer;
            }
            // This is the one place a delivery attempt is counted; a prior
            // attempt (including one recovered from the WAL) marks the
            // message redelivered.
            msg.delivery_count += 1;
            if msg.delivery_count > 1 {
                msg.redelivered = true;
            }
            let tag = next_tag();
            let consumer = &mut self.consumers[idx];
            consumer.in_flight += 1;
            self.rr_cursor = (idx + 1) % n;
            self.delivered += 1;
            self.unacked.insert(
                tag,
                InFlight {
                    // Refcount bumps only: body/props/names are shared, so
                    // keeping the unacked copy costs no payload duplication.
                    message: msg.clone(),
                    consumer_tag: consumer.consumer_tag.clone(),
                    connection: consumer.connection,
                },
            );
            out.push(Assignment {
                consumer_tag: consumer.consumer_tag.clone(),
                connection: consumer.connection,
                delivery_tag: tag,
                message: msg,
                offset: None,
            });
        }
        out
    }

    /// Acknowledge a delivery. Returns the message id for WAL retirement,
    /// or None if the tag is unknown (double-ack is idempotent).
    pub fn ack(&mut self, delivery_tag: u64) -> Option<u64> {
        if let Some(s) = self.stream.as_mut() {
            let msg_id = s.ack(delivery_tag)?;
            self.acked += 1;
            return Some(msg_id);
        }
        let inflight = self.unacked.remove(&delivery_tag)?;
        if let Some(c) =
            self.consumers.iter_mut().find(|c| c.consumer_tag == inflight.consumer_tag)
        {
            c.in_flight = c.in_flight.saturating_sub(1);
        }
        self.acked += 1;
        Some(inflight.message.msg_id)
    }

    /// Negative-acknowledge. When `requeue` (and the message is under the
    /// `max_delivery` cap), it returns to the front of its priority lane
    /// marked redelivered; otherwise it leaves the queue dead — the core
    /// routes it to the queue's DLX or retires it.
    pub fn nack(&mut self, delivery_tag: u64, requeue: bool) -> NackOutcome {
        if let Some(s) = self.stream.as_mut() {
            // The log is immutable: a rejected entry cannot leave it (it
            // stays readable by every other group), so reject just marks
            // it consumed for this group. Either way the outcome is
            // `Requeued` — streams never feed the dead-letter pipeline,
            // and the core skips WAL requeue records for them.
            return if requeue {
                match s.requeue(delivery_tag) {
                    Some(msg_id) => {
                        self.requeued += 1;
                        NackOutcome::Requeued { msg_id, delivery_count: 1 }
                    }
                    None => NackOutcome::Unknown,
                }
            } else {
                match s.ack(delivery_tag) {
                    Some(msg_id) => {
                        self.acked += 1;
                        NackOutcome::Requeued { msg_id, delivery_count: 1 }
                    }
                    None => NackOutcome::Unknown,
                }
            };
        }
        let Some(inflight) = self.unacked.remove(&delivery_tag) else {
            return NackOutcome::Unknown;
        };
        if let Some(c) =
            self.consumers.iter_mut().find(|c| c.consumer_tag == inflight.consumer_tag)
        {
            c.in_flight = c.in_flight.saturating_sub(1);
        }
        let mut msg = inflight.message;
        if requeue && !self.over_delivery_cap(&msg) {
            msg.redelivered = true;
            self.track_in(&msg);
            let lane = msg.lane();
            let (msg_id, delivery_count) = (msg.msg_id, msg.delivery_count);
            self.ready[lane].push_front(msg);
            self.ready_count += 1;
            self.requeued += 1;
            NackOutcome::Requeued { msg_id, delivery_count }
        } else {
            let reason =
                if requeue { DeadReason::MaxDelivery } else { DeadReason::Rejected };
            self.dead_lettered += 1;
            NackOutcome::Dead(DeadLettered { reason, message: msg })
        }
    }

    /// Return an unacked message to the head of its lane *without*
    /// counting the attempt — used when a delivery's send never reached
    /// the consumer (session channel already torn down). Never
    /// dead-letters: a failed send is the broker's fault, not the
    /// message's.
    pub fn requeue_undelivered(&mut self, delivery_tag: u64) -> bool {
        if let Some(s) = self.stream.as_mut() {
            if s.requeue(delivery_tag).is_some() {
                self.requeued += 1;
                return true;
            }
            return false;
        }
        let Some(inflight) = self.unacked.remove(&delivery_tag) else { return false };
        if let Some(c) =
            self.consumers.iter_mut().find(|c| c.consumer_tag == inflight.consumer_tag)
        {
            c.in_flight = c.in_flight.saturating_sub(1);
        }
        let mut msg = inflight.message;
        msg.delivery_count = msg.delivery_count.saturating_sub(1);
        self.track_in(&msg);
        let lane = msg.lane();
        self.ready[lane].push_front(msg);
        self.ready_count += 1;
        self.requeued += 1;
        true
    }

    /// Requeue every unacked message belonging to `connection` and remove
    /// its consumers — what the broker does when a client dies (abrupt
    /// shutdown, two missed heartbeats). The outcome carries the now-dead
    /// delivery tags (caller prunes its delivery index; requeued messages
    /// get fresh tags on redelivery), any messages over the `max_delivery`
    /// cap (dead-lettered instead of requeued — a crash counts as a failed
    /// attempt, so a poison task cannot crash-loop forever), and the
    /// requeue log for durable WAL records.
    ///
    /// Requeued messages are re-inserted at the *front* of their priority
    /// lane in ascending delivery-tag order, so a batch taken in order
    /// `m1, m2, m3` comes back as `m1, m2, m3` — redelivery preserves the
    /// original FIFO order.
    pub fn drop_connection(&mut self, connection: u64) -> DropOutcome {
        if let Some(s) = self.stream.as_mut() {
            // Offsets go back to their group's redelivery set; surviving
            // members re-cover the dead member's partitions on the next
            // assignment round. Nothing can dead-letter (the log is
            // immutable) and the WAL holds no per-stream requeue state.
            let (dead_tags, requeued) = s.drop_connection(connection);
            self.requeued += requeued;
            return DropOutcome { dead_tags, dead: Vec::new(), requeued: Vec::new() };
        }
        let mut tags: Vec<u64> = self
            .unacked
            .iter()
            .filter(|(_, f)| f.connection == connection)
            .map(|(t, _)| *t)
            .collect();
        // Descending tag order + push_front = oldest delivery ends up first.
        tags.sort_unstable_by(|a, b| b.cmp(a));
        let mut dead = Vec::new();
        let mut requeued = Vec::new();
        for tag in &tags {
            let inflight = self.unacked.remove(tag).unwrap();
            let mut msg = inflight.message;
            if self.over_delivery_cap(&msg) {
                self.dead_lettered += 1;
                dead.push(DeadLettered { reason: DeadReason::MaxDelivery, message: msg });
                continue;
            }
            msg.redelivered = true;
            requeued.push((msg.msg_id, msg.delivery_count));
            self.track_in(&msg);
            let lane = msg.lane();
            self.ready[lane].push_front(msg);
            self.ready_count += 1;
            self.requeued += 1;
        }
        self.consumers.retain(|c| c.connection != connection);
        if self.rr_cursor >= self.consumers.len() {
            self.rr_cursor = 0;
        }
        DropOutcome { dead_tags: tags, dead, requeued }
    }

    /// Drop all ready messages; returns their ids (for WAL retirement)
    /// paired with the paged-body locator of any evicted message (the
    /// caller releases spill-file space for those).
    pub fn purge(&mut self) -> Vec<(u64, Option<BodyLocator>)> {
        if let Some(s) = self.stream.as_mut() {
            // Stream entries never had WAL publish records or spill-file
            // space, so there is nothing for the core to retire/release —
            // the store drops its own segments.
            let next = s.next_offset;
            s.truncate_to(next);
            if let Some(store) = s.store.as_mut() {
                if let Err(e) = store.purge(next) {
                    log::error!("stream: purge of segment files failed: {e}");
                }
            }
            return Vec::new();
        }
        let mut ids = Vec::with_capacity(self.ready_count);
        for lane in &mut self.ready {
            for m in lane.drain(..) {
                ids.push((m.msg_id, m.paged));
            }
        }
        self.ready_count = 0;
        self.ttl_ready = 0;
        self.earliest_deadline = None;
        self.resident_bytes = 0;
        self.paged_bytes = 0;
        self.paged_count = 0;
        ids
    }

    /// Take the messages that expired during assignment since the last
    /// call (the core dead-letters them to the queue's DLX, or retires
    /// them from the WAL when there is none).
    pub fn drain_expired(&mut self) -> Vec<QueuedMessage> {
        std::mem::take(&mut self.expired_buf)
    }

    /// Remove expired ready messages (periodic sweep) and return them —
    /// the core dead-letters or retires them; the sweep itself no longer
    /// makes anything vanish without a trace.
    ///
    /// O(1) for the common case: when no ready message carries a TTL, or
    /// the earliest tracked deadline is still in the future, the scan is
    /// skipped entirely — a broker full of TTL-less queues pays nothing
    /// for the sweep. A scan recomputes the bound exactly.
    pub fn sweep_expired(&mut self, now: Instant) -> Vec<QueuedMessage> {
        if self.ttl_ready == 0 {
            return Vec::new();
        }
        if let Some(earliest) = self.earliest_deadline {
            if now < earliest {
                return Vec::new();
            }
        }
        let mut swept = Vec::new();
        let mut remaining = 0usize;
        let mut earliest: Option<Instant> = None;
        let mut resident = 0u64;
        let mut paged = 0u64;
        let mut paged_count = 0usize;
        for lane in &mut self.ready {
            // `retain` cannot move the element out; collect indices first
            // would also copy — a drain-and-rebuild keeps it simple and
            // runs only when the deadline gate is already open.
            let mut kept = VecDeque::with_capacity(lane.len());
            for m in lane.drain(..) {
                if m.expired(now) {
                    swept.push(m);
                } else {
                    if let Some(d) = m.deadline {
                        remaining += 1;
                        earliest = Some(earliest.map_or(d, |e| e.min(d)));
                    }
                    match m.paged {
                        Some(loc) => {
                            paged += u64::from(loc.len);
                            paged_count += 1;
                        }
                        None => resident += m.body.len() as u64,
                    }
                    kept.push_back(m);
                }
            }
            *lane = kept;
        }
        self.ready_count -= swept.len();
        self.expired += swept.len() as u64;
        self.ttl_ready = remaining;
        self.earliest_deadline = earliest;
        self.resident_bytes = resident;
        self.paged_bytes = paged;
        self.paged_count = paged_count;
        swept
    }

    /// Body bytes of ready messages currently held in memory.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Body bytes of ready messages evicted to the WAL / spill file.
    pub fn paged_bytes(&self) -> u64 {
        self.paged_bytes
    }

    /// Ready messages whose body is currently evicted.
    pub fn paged_len(&self) -> usize {
        self.paged_count
    }

    /// Evict message bodies from the *tail* of the ready lanes (reverse
    /// assignment order: lowest priority first, newest first) until the
    /// queue's resident bytes drop to `threshold` — keeping at least the
    /// first `head_window` messages in assignment order resident so the
    /// next dispatch rounds never stall on disk.
    ///
    /// `page` maps a message to the locator its body can be re-read from:
    /// for durable messages that is the already-written WAL record
    /// (`msg.stored`, free); for non-durable ones the backend appends the
    /// body to its spill file. Returning `None` (spill I/O failure) leaves
    /// the message resident — paging must never lose a body.
    ///
    /// Returns the number of bodies evicted. Pure bookkeeping aside from
    /// the `page` callback; the caller holds the shard lock, so the
    /// callback must only append to the backend's spill file (a leaf
    /// lock), never re-enter the shard.
    pub fn page_out_tail(
        &mut self,
        threshold: u64,
        head_window: usize,
        mut page: impl FnMut(&QueuedMessage) -> Option<BodyLocator>,
    ) -> usize {
        if self.resident_bytes <= threshold {
            return 0;
        }
        let mut evicted = 0usize;
        // Position from the tail: assignment position = ready_count-1-k for
        // the k-th message visited. Stop once inside the head window.
        let mut from_tail = 0usize;
        let protect = head_window;
        'lanes: for lane in 0..PRIORITY_LANES {
            let len = self.ready[lane].len();
            for i in (0..len).rev() {
                if self.resident_bytes <= threshold {
                    break 'lanes;
                }
                let position = self.ready_count - 1 - from_tail;
                from_tail += 1;
                if position < protect {
                    break 'lanes;
                }
                let msg = &mut self.ready[lane][i];
                if msg.paged.is_some() || msg.body.is_empty() {
                    continue;
                }
                let Some(loc) = page(msg) else { continue };
                let freed = msg.body.len() as u64;
                msg.body = Bytes::new();
                msg.paged = Some(loc);
                self.resident_bytes = self.resident_bytes.saturating_sub(freed);
                self.paged_bytes += u64::from(loc.len);
                self.paged_count += 1;
                self.page_outs += 1;
                evicted += 1;
            }
        }
        evicted
    }

    /// The paged messages inside the head window (first `limit` messages
    /// in assignment order) — what the dispatch pump must page back in
    /// before assignment can proceed. Read-only; bodies are restored with
    /// [`Queue::restore_body`] after the reads happen off the shard lock.
    pub fn paged_head(&self, limit: usize) -> Vec<(u64, BodyLocator)> {
        if self.paged_count == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut seen = 0usize;
        for lane in (0..PRIORITY_LANES).rev() {
            for m in &self.ready[lane] {
                if seen >= limit {
                    return out;
                }
                seen += 1;
                if let Some(loc) = m.paged {
                    out.push((m.msg_id, loc));
                }
            }
        }
        out
    }

    /// Re-attach a body read back from disk to a still-ready paged
    /// message. Returns the locator that was cleared (`Some` exactly when
    /// the restore happened — the caller then releases spill-file space);
    /// `None` means the message left the queue in the meantime (purged,
    /// expired, dropped) and the *removal* path owns the release.
    pub fn restore_body(&mut self, msg_id: u64, body: Bytes) -> Option<BodyLocator> {
        for lane in 0..PRIORITY_LANES {
            for m in self.ready[lane].iter_mut() {
                if m.msg_id == msg_id {
                    let loc = m.paged.take()?;
                    self.paged_bytes = self.paged_bytes.saturating_sub(u64::from(loc.len));
                    self.paged_count = self.paged_count.saturating_sub(1);
                    self.resident_bytes += body.len() as u64;
                    m.body = body;
                    self.page_ins += 1;
                    return Some(loc);
                }
            }
        }
        None
    }

    /// Wrap dead messages with this queue's dead-letter routing config —
    /// everything the core needs once the shard lock is gone.
    pub fn pend_dead(&self, dead: Vec<DeadLettered>) -> Vec<PendingDead> {
        dead.into_iter()
            .map(|d| PendingDead {
                source: Arc::clone(&self.name),
                dead_letter_exchange: self.options.dead_letter_exchange.clone(),
                dead_letter_routing_key: self.options.dead_letter_routing_key.clone(),
                durable: self.options.durable,
                reason: d.reason,
                message: d.message,
            })
            .collect()
    }

    /// All messages (ready + unacked) — used for durable-queue snapshots.
    pub fn all_messages(&self) -> Vec<&QueuedMessage> {
        let mut v: Vec<&QueuedMessage> = Vec::with_capacity(self.ready_count + self.unacked.len());
        for lane in (0..PRIORITY_LANES).rev() {
            v.extend(self.ready[lane].iter());
        }
        v.extend(self.unacked.values().map(|f| &f.message));
        v
    }

    // --- Stream queue API (no-ops / `None` on work queues) ---

    /// True when this queue is a `stream` (append-only log) queue.
    pub fn is_stream(&self) -> bool {
        self.stream.is_some()
    }

    /// Offset the next stream publish will take.
    pub fn stream_next_offset(&self) -> u64 {
        self.stream.as_ref().map_or(0, |s| s.next_offset)
    }

    /// Oldest offset retention still holds.
    pub fn stream_base_offset(&self) -> u64 {
        self.stream.as_ref().map_or(0, |s| s.base_offset)
    }

    /// A group's committed watermark (offsets below it are consumed).
    pub fn stream_group_committed(&self, group: &str) -> Option<u64> {
        self.stream.as_ref()?.groups.get(group).map(|g| g.committed)
    }

    /// Entry body bytes currently resident in memory (bounded by the
    /// resident window whenever a store is attached).
    pub fn stream_resident_bytes(&self) -> u64 {
        self.stream.as_ref().map_or(0, |s| s.resident_bytes)
    }

    /// Bytes the stream's segment files occupy on disk.
    pub fn stream_disk_bytes(&self) -> u64 {
        self.stream
            .as_ref()
            .and_then(|s| s.store.as_ref())
            .map_or(0, |store| store.disk_bytes())
    }

    /// Commit a group's position through `offset` (inclusive) — the
    /// explicit `StreamCommit` frame. A backward offset is a seek: the
    /// group replays from there. Returns false if the queue is not a
    /// stream or the group does not exist.
    pub fn stream_commit(&mut self, group: &str, offset: u64) -> bool {
        let Some(s) = self.stream.as_mut() else { return false };
        let Some(g) = s.groups.get_mut(group) else { return false };
        let target = offset.saturating_add(1);
        if target >= g.committed {
            g.committed = target;
            g.cursor = g.cursor.max(target);
            g.acked = g.acked.split_off(&target);
            g.redeliver = g.redeliver.split_off(&target);
        } else {
            g.seek(target);
        }
        let committed = g.committed;
        if let Some(store) = s.store.as_mut() {
            if let Err(e) = store.record_commit(group, committed) {
                log::error!("stream: commit record for group {group:?} failed: {e}");
            }
        }
        true
    }

    /// Apply segment retention (periodic sweep). Returns how many entries
    /// were truncated from the front of the log.
    pub fn stream_retain(&mut self) -> usize {
        let Some(s) = self.stream.as_mut() else { return 0 };
        let Some(store) = s.store.as_mut() else { return 0 };
        match store.retain() {
            Ok(Some(new_base)) => {
                let old = s.base_offset;
                s.truncate_to(new_base);
                new_base.saturating_sub(old) as usize
            }
            Ok(None) => 0,
            Err(e) => {
                log::error!("stream: retention sweep failed: {e}");
                0
            }
        }
    }

    /// Attach the backing store after recovery: rebuilds the entry index
    /// (bodies left on disk) and restores each group at its committed
    /// offset. Replaces any previous store/state.
    pub fn attach_stream_store(&mut self, store: StreamStore, recovered: RecoveredStream) {
        let Some(s) = self.stream.as_mut() else { return };
        s.entries.clear();
        s.resident.clear();
        s.resident_bytes = 0;
        s.base_offset = recovered.base_offset;
        s.next_offset = recovered.next_offset;
        // Intern repeated exchange/routing-key names: replayed entries
        // overwhelmingly share them with their predecessor.
        let mut last_ex: Option<Arc<str>> = None;
        let mut last_rk: Option<Arc<str>> = None;
        for e in recovered.entries {
            let exchange = match &last_ex {
                Some(a) if **a == *e.exchange => Arc::clone(a),
                _ => {
                    let a: Arc<str> = e.exchange.into();
                    last_ex = Some(Arc::clone(&a));
                    a
                }
            };
            let routing_key = match &last_rk {
                Some(a) if **a == *e.routing_key => Arc::clone(a),
                _ => {
                    let a: Arc<str> = e.routing_key.into();
                    last_rk = Some(Arc::clone(&a));
                    a
                }
            };
            s.entries.push_back(StreamEntry {
                offset: e.offset,
                msg_id: e.msg_id,
                exchange,
                routing_key,
                body: Bytes::new(),
                props: e.props,
                locator: Some(e.locator),
            });
        }
        for (gname, committed) in recovered.commits {
            s.groups.insert(gname, StreamGroup::new(committed.min(recovered.next_offset)));
        }
        s.store = Some(store);
    }

    /// Queue statistics as a wire value (answering `Status` requests).
    pub fn stats(&self) -> Value {
        let mut pairs = vec![
            ("ready", Value::from(self.ready_len())),
            ("unacked", Value::from(self.unacked_len())),
            ("paged", Value::from(self.paged_len())),
            ("bytes_resident", Value::from(self.resident_bytes)),
            ("bytes_paged", Value::from(self.paged_bytes)),
            ("consumers", Value::from(self.consumer_count())),
            ("published", Value::from(self.published)),
            ("delivered", Value::from(self.delivered)),
            ("acked", Value::from(self.acked)),
            ("requeued", Value::from(self.requeued)),
            ("expired", Value::from(self.expired)),
            ("dropped_overflow", Value::from(self.dropped_overflow)),
            ("dead_lettered", Value::from(self.dead_lettered)),
        ];
        if let Some(s) = &self.stream {
            pairs.push(("stream_next_offset", Value::from(s.next_offset)));
            pairs.push(("stream_base_offset", Value::from(s.base_offset)));
            pairs.push(("stream_groups", Value::from(s.groups.len())));
            pairs.push(("stream_bytes_resident", Value::from(s.resident_bytes)));
        }
        Value::map(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::MessageProps;
    use crate::proputil::{run_prop, Rng};
    use std::time::Duration;

    fn msg(id: u64, priority: u8) -> QueuedMessage {
        QueuedMessage {
            msg_id: id,
            exchange: "".into(),
            routing_key: "q".into(),
            body: Bytes::encode(&Value::I64(id as i64)),
            props: MessageProps { priority, ..Default::default() }.into(),
            deadline: None,
            redelivered: false,
            delivery_count: 0,
            stored: None,
            paged: None,
        }
    }

    /// Publish expecting clean acceptance (no overflow displacement).
    fn put(q: &mut Queue, m: QueuedMessage, now: Instant) {
        let out = q.publish(m, now);
        assert!(out.accepted);
        assert!(out.dead.is_empty());
    }

    fn consumer(tag: &str, conn: u64, prefetch: u32) -> Consumer {
        Consumer { consumer_tag: tag.into(), connection: conn, prefetch, in_flight: 0 }
    }

    fn tagger() -> impl FnMut() -> u64 {
        let mut t = 0;
        move || {
            t += 1;
            t
        }
    }

    #[test]
    fn fifo_within_priority() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..5 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        let ids: Vec<u64> = a.iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn higher_priority_first() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        put(&mut q, msg(1, 0), now);
        put(&mut q, msg(2, 9), now);
        put(&mut q, msg(3, 5), now);
        q.add_consumer(consumer("c1", 1, 0));
        let ids: Vec<u64> = q.assign(now, tagger()).iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn at_most_one_consumer_per_message() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..100 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 0));
        q.add_consumer(consumer("c2", 2, 0));
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 100);
        // Every message delivered exactly once.
        let mut ids: Vec<u64> = a.iter().map(|x| x.message.msg_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..100).collect::<Vec<_>>());
        // Round-robin split.
        let c1 = a.iter().filter(|x| x.consumer_tag == "c1").count();
        assert_eq!(c1, 50);
    }

    #[test]
    fn prefetch_limits_in_flight() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..10 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 1));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 1, "prefetch=1 allows a single in-flight message");
        assert_eq!(q.ready_len(), 9);
        assert_eq!(q.unacked_len(), 1);
        // Ack frees the slot; next assign delivers exactly one more.
        assert!(q.ack(a[0].delivery_tag).is_some());
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].message.msg_id, 1);
    }

    #[test]
    fn ack_is_idempotent() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        assert!(q.ack(a[0].delivery_tag).is_some());
        assert!(q.ack(a[0].delivery_tag).is_none());
        assert_eq!(q.acked, 1);
    }

    #[test]
    fn nack_requeue_preserves_message_marks_redelivered() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert!(!a[0].message.redelivered);
        assert!(matches!(q.nack(a[0].delivery_tag, true), NackOutcome::Requeued { .. }));
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert!(b[0].message.redelivered);
    }

    #[test]
    fn nack_drop_discards() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        match q.nack(a[0].delivery_tag, false) {
            NackOutcome::Dead(d) => {
                assert_eq!(d.reason, DeadReason::Rejected);
                assert_eq!(d.message.msg_id, 0);
            }
            _ => panic!("expected dead"),
        }
        assert_eq!(q.ready_len(), 0);
        assert_eq!(q.unacked_len(), 0);
    }

    #[test]
    fn connection_death_requeues_all_unacked() {
        // The headline robustness property: abrupt consumer death loses
        // nothing.
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..10 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_consumer(consumer("dead", 7, 0));
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 10);
        assert_eq!(q.drop_connection(7).dead_tags.len(), 10);
        assert_eq!(q.ready_len(), 10);
        assert_eq!(q.unacked_len(), 0);
        assert_eq!(q.consumer_count(), 0);
        // A new consumer picks everything up, marked redelivered, in the
        // original FIFO order.
        q.add_consumer(consumer("alive", 8, 0));
        let b = q.assign(now, tagger());
        assert_eq!(b.len(), 10);
        assert!(b.iter().all(|x| x.message.redelivered));
        let ids: Vec<u64> = b.iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>(), "redelivery must preserve order");
    }

    #[test]
    fn assign_filter_skips_unready_connections_without_stalling_others() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..6 {
            put(&mut q, msg(i, 0), now);
        }
        // Two consumers on distinct connections; connection 7 is paused
        // (over-cap outbox).
        q.add_consumer(consumer("slow", 7, 0));
        q.add_consumer(consumer("fast", 8, 0));
        let mut tags = tagger();
        let a = q.assign_up_to_filtered(now, 4, &mut tags, |conn| conn != 7);
        assert_eq!(a.len(), 4, "the ready connection absorbs the whole batch");
        assert!(a.iter().all(|x| x.connection == 8));
        // Messages stay ready (not in-flight) for the paused connection.
        assert_eq!(q.ready_len(), 2);
        // Resume: the filter opens and the paused consumer gets its share.
        let b = q.assign_up_to_filtered(now, 4, &mut tags, |_| true);
        assert_eq!(b.len(), 2);
        assert!(b.iter().any(|x| x.connection == 7));
        // Nothing ready and nobody gains in-flight slots spuriously.
        assert_eq!(q.ready_len(), 0);
        assert_eq!(q.unacked_len(), 6);
    }

    #[test]
    fn assign_up_to_bounds_batch_size() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..10 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign_up_to(now, 4, &mut tags);
        assert_eq!(a.len(), 4);
        assert_eq!(q.ready_len(), 6);
        let b = q.assign_up_to(now, 100, &mut tags);
        assert_eq!(b.len(), 6);
        assert_eq!(b[0].message.msg_id, 4, "batches drain in FIFO order");
    }

    #[test]
    fn expired_messages_not_delivered() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let mut m = msg(0, 0);
        m.props = MessageProps { expiration_ms: Some(10), ..Default::default() }.into();
        put(&mut q, m, now);
        put(&mut q, msg(1, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let later = now + Duration::from_millis(50);
        let a = q.assign(later, tagger());
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].message.msg_id, 1);
        assert_eq!(q.expired, 1);
    }

    #[test]
    fn queue_default_ttl_applied() {
        let mut q = Queue::new(
            "q",
            QueueOptions { default_ttl_ms: Some(5), ..Default::default() },
            None,
        );
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        let swept: Vec<u64> =
            q.sweep_expired(now + Duration::from_millis(20)).iter().map(|m| m.msg_id).collect();
        assert_eq!(swept, vec![0]);
        assert_eq!(q.ready_len(), 0);
    }

    #[test]
    fn sweep_skip_bookkeeping_tracks_ttl_messages() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        // No TTLs anywhere: nothing pending, sweep is a no-op.
        put(&mut q, msg(0, 0), now);
        assert_eq!(q.ttl_pending(), 0);
        assert!(q.sweep_expired(now + Duration::from_secs(60)).is_empty());
        assert_eq!(q.ready_len(), 1);
        // A TTL'd message is tracked in...
        let mut m = msg(1, 0);
        m.props = MessageProps { expiration_ms: Some(10), ..Default::default() }.into();
        put(&mut q, m, now);
        assert_eq!(q.ttl_pending(), 1);
        // ...and the sweep gate stays closed before its deadline.
        assert!(q.sweep_expired(now).is_empty());
        assert_eq!(q.ready_len(), 2);
        // After the deadline, exactly the TTL'd message is swept and the
        // tracking resets.
        assert_eq!(
            q.sweep_expired(now + Duration::from_millis(50))
                .iter()
                .map(|m| m.msg_id)
                .collect::<Vec<u64>>(),
            vec![1]
        );
        assert_eq!(q.ttl_pending(), 0);
        assert_eq!(q.ready_len(), 1);
    }

    #[test]
    fn sweep_skip_cleared_on_pop_restored_on_requeue() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let mut m = msg(0, 0);
        m.props = MessageProps { expiration_ms: Some(10_000), ..Default::default() }.into();
        put(&mut q, m, now);
        assert_eq!(q.ttl_pending(), 1);
        // Delivery pops it out of ready: no TTL'd ready message remains.
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 1);
        assert_eq!(q.ttl_pending(), 0);
        // Requeue puts it (and its deadline) back under tracking.
        assert!(matches!(q.nack(a[0].delivery_tag, true), NackOutcome::Requeued { .. }));
        assert_eq!(q.ttl_pending(), 1);
        // Connection-death requeue is tracked too.
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert_eq!(q.ttl_pending(), 0);
        let _ = q.drop_connection(1);
        assert_eq!(q.ttl_pending(), 1);
        // Purge resets everything.
        q.purge();
        assert_eq!(q.ttl_pending(), 0);
        assert!(q.sweep_expired(now + Duration::from_secs(60)).is_empty());
    }

    #[test]
    fn sweep_bound_is_conservative_after_pop() {
        // Two TTL'd messages; pop the earlier one. The retained bound may
        // now be stale (earlier than any live deadline) — the sweep must
        // still expire correctly, never skip wrongly.
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let mut early = msg(0, 0);
        early.props = MessageProps { expiration_ms: Some(10), ..Default::default() }.into();
        put(&mut q, early, now);
        let mut late = msg(1, 0);
        late.props = MessageProps { expiration_ms: Some(1000), ..Default::default() }.into();
        put(&mut q, late, now);
        q.add_consumer(consumer("c1", 1, 1));
        let a = q.assign(now, tagger()); // pops msg 0 (prefetch 1)
        assert_eq!(a[0].message.msg_id, 0);
        assert_eq!(q.ttl_pending(), 1);
        // Before either deadline: a scan may run (stale bound) but must
        // remove nothing; after msg 1's deadline it must expire it.
        assert!(q.sweep_expired(now).is_empty());
        assert_eq!(
            q.sweep_expired(now + Duration::from_secs(5))
                .iter()
                .map(|m| m.msg_id)
                .collect::<Vec<u64>>(),
            vec![1]
        );
        assert_eq!(q.ttl_pending(), 0);
    }

    #[test]
    fn max_length_drops_oldest() {
        let mut q = Queue::new(
            "q",
            QueueOptions { max_length: Some(3), ..Default::default() },
            None,
        );
        let now = Instant::now();
        let mut displaced = Vec::new();
        for i in 0..5 {
            let out = q.publish(msg(i, 0), now);
            assert!(out.accepted, "drop-head always accepts the incoming message");
            displaced.extend(out.dead);
        }
        assert_eq!(q.ready_len(), 3);
        assert_eq!(q.dropped_overflow, 2);
        assert_eq!(q.dead_lettered, 2);
        let dead_ids: Vec<u64> = displaced.iter().map(|d| d.message.msg_id).collect();
        assert_eq!(dead_ids, vec![0, 1], "oldest evicted first, handed back for dead-lettering");
        assert!(displaced.iter().all(|d| d.reason == DeadReason::Overflow));
        q.add_consumer(consumer("c1", 1, 0));
        let ids: Vec<u64> = q.assign(now, tagger()).iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn reject_new_overflow_refuses_incoming() {
        let mut q = Queue::new(
            "q",
            QueueOptions {
                max_length: Some(2),
                overflow: OverflowPolicy::RejectNew,
                ..Default::default()
            },
            None,
        );
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        put(&mut q, msg(1, 0), now);
        let out = q.publish(msg(2, 0), now);
        assert!(!out.accepted);
        assert_eq!(out.dead.len(), 1);
        assert_eq!(out.dead[0].message.msg_id, 2, "the incoming message is the casualty");
        assert_eq!(out.dead[0].reason, DeadReason::Overflow);
        assert_eq!(q.ready_len(), 2, "queued work is untouched");
        assert_eq!(q.published, 2, "a refused message was never published");
        // Room frees up after a pop; publishes resume.
        q.add_consumer(consumer("c1", 1, 1));
        let a = q.assign(now, tagger());
        assert!(q.ack(a[0].delivery_tag).is_some());
        put(&mut q, msg(3, 0), now);
    }

    #[test]
    fn max_delivery_cap_blocks_requeue() {
        let mut q = Queue::new(
            "q",
            QueueOptions { max_delivery: Some(2), ..Default::default() },
            None,
        );
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        // First delivery: requeue allowed.
        let a = q.assign(now, &mut tags);
        assert_eq!(a[0].message.delivery_count, 1);
        match q.nack(a[0].delivery_tag, true) {
            NackOutcome::Requeued { delivery_count, .. } => assert_eq!(delivery_count, 1),
            _ => panic!("first requeue must be allowed"),
        }
        // Second delivery: the cap refuses the requeue.
        let b = q.assign(now, &mut tags);
        assert_eq!(b[0].message.delivery_count, 2);
        assert!(b[0].message.redelivered);
        match q.nack(b[0].delivery_tag, true) {
            NackOutcome::Dead(d) => {
                assert_eq!(d.reason, DeadReason::MaxDelivery);
                assert_eq!(d.message.delivery_count, 2);
            }
            _ => panic!("cap must dead-letter the second requeue"),
        }
        assert_eq!(q.ready_len(), 0);
        assert_eq!(q.unacked_len(), 0);
        assert_eq!(q.dead_lettered, 1);
    }

    #[test]
    fn connection_death_over_cap_dead_letters() {
        let mut q = Queue::new(
            "q",
            QueueOptions { max_delivery: Some(1), ..Default::default() },
            None,
        );
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        put(&mut q, msg(1, 0), now);
        q.add_consumer(consumer("c1", 7, 0));
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 2);
        let out = q.drop_connection(7);
        assert_eq!(out.dead_tags.len(), 2);
        assert_eq!(out.dead.len(), 2, "cap of 1: a crash consumes the only attempt");
        assert!(out.requeued.is_empty());
        assert_eq!(q.ready_len(), 0);
    }

    #[test]
    fn requeue_undelivered_does_not_count_attempt() {
        let mut q = Queue::new(
            "q",
            QueueOptions { max_delivery: Some(1), ..Default::default() },
            None,
        );
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(a[0].message.delivery_count, 1);
        // The send never landed: attempt refunded, message ready again.
        assert!(q.requeue_undelivered(a[0].delivery_tag));
        assert_eq!(q.ready_len(), 1);
        // The refunded attempt means the next real delivery is attempt 1
        // again — a failed send can never push a message over the cap.
        let b = q.assign(now, &mut tags);
        assert_eq!(b[0].message.delivery_count, 1);
        assert!(!q.requeue_undelivered(999), "unknown tag is a no-op");
    }

    #[test]
    fn pend_dead_carries_queue_dlx_config() {
        let mut q = Queue::new(
            "q",
            QueueOptions {
                durable: true,
                dead_letter_exchange: Some("dlx".into()),
                dead_letter_routing_key: Some("graveyard".into()),
                ..Default::default()
            },
            None,
        );
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        let NackOutcome::Dead(d) = q.nack(a[0].delivery_tag, false) else {
            panic!("expected dead")
        };
        let pd = q.pend_dead(vec![d]);
        assert_eq!(pd.len(), 1);
        assert_eq!(&*pd[0].source, "q");
        assert_eq!(pd[0].dead_letter_exchange.as_deref(), Some("dlx"));
        assert_eq!(pd[0].dead_letter_routing_key.as_deref(), Some("graveyard"));
        assert!(pd[0].durable);
        assert_eq!(pd[0].reason, DeadReason::Rejected);
    }

    #[test]
    fn duplicate_consumer_tag_rejected() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        assert!(q.add_consumer(consumer("c1", 1, 0)));
        assert!(!q.add_consumer(consumer("c1", 2, 0)));
    }

    #[test]
    fn purge_returns_ids() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..4 {
            put(&mut q, msg(i, (i % 2) as u8), now);
        }
        let mut ids: Vec<u64> = q.purge().into_iter().map(|(id, _)| id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(q.ready_len(), 0);
    }

    fn spill_locator(len: u32) -> BodyLocator {
        BodyLocator { segment: u32::MAX, generation: 0, offset: 0, len }
    }

    #[test]
    fn page_out_respects_threshold_and_head_window() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..10 {
            put(&mut q, msg(i, 0), now);
        }
        let total = q.resident_bytes();
        assert!(total > 0);
        // Evict everything past the first 4 messages.
        let evicted = q.page_out_tail(0, 4, |m| spill_locator(m.body.len() as u32));
        assert_eq!(evicted, 6, "everything outside the head window pages out");
        assert_eq!(q.paged_len(), 6);
        assert!(q.resident_bytes() < total);
        assert!(q.paged_bytes() > 0);
        // The head window (oldest messages) stays resident and deliverable.
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 4, "assignment stops at the paged boundary");
        let ids: Vec<u64> = a.iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert!(a.iter().all(|x| !x.message.body.is_empty()));
        assert_eq!(q.ready_len(), 6, "paged tail stays queued, never handed out");
    }

    #[test]
    fn page_in_restores_delivery_in_fifo_order() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let bodies: Vec<Bytes> = (0..6i64).map(|i| Bytes::encode(&Value::I64(i))).collect();
        for i in 0..6u64 {
            put(&mut q, msg(i, 0), now);
        }
        q.page_out_tail(0, 0, |m| spill_locator(m.body.len() as u32));
        assert_eq!(q.paged_len(), 6);
        assert_eq!(q.resident_bytes(), 0);
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        assert!(q.assign(now, &mut tags).is_empty(), "fully paged queue assigns nothing");
        // Page the head window back in, as the dispatch pump would.
        let head = q.paged_head(4);
        assert_eq!(head.len(), 4);
        assert_eq!(head[0].0, 0, "head window is assignment order");
        for (id, _loc) in head {
            let released = q.restore_body(id, bodies[id as usize].clone());
            assert!(released.is_some(), "restore returns the cleared locator");
        }
        assert_eq!(q.page_ins, 4);
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 4);
        let ids: Vec<u64> = a.iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "page-in preserves FIFO order");
        assert!(a.iter().all(|x| !x.message.body.is_empty()));
        // Double-restore is idempotent; vanished messages return None.
        assert!(q.restore_body(0, bodies[0].clone()).is_none());
        assert!(q.restore_body(99, bodies[0].clone()).is_none());
    }

    #[test]
    fn durable_stored_locator_pages_out_for_free() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let mut m = msg(1, 0);
        m.stored = Some(BodyLocator { segment: 0, generation: 0, offset: 64, len: 9 });
        put(&mut q, m, now);
        put(&mut q, msg(2, 0), now);
        // The pager consults `stored` first — no spill write for durable
        // bodies (mirrors the backend's page_out).
        let mut spilled = 0;
        q.page_out_tail(0, 0, |m| {
            m.stored.or_else(|| {
                spilled += 1;
                Some(spill_locator(m.body.len() as u32))
            })
        });
        assert_eq!(q.paged_len(), 2);
        assert_eq!(spilled, 1, "only the non-durable body hits the spill file");
    }

    #[test]
    fn byte_accounting_survives_requeue_and_purge() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..4 {
            put(&mut q, msg(i, 0), now);
        }
        let resident = q.resident_bytes();
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(q.resident_bytes(), 0, "in-flight bodies are not ready-resident");
        // Requeue brings the bytes back.
        for x in &a {
            assert!(matches!(q.nack(x.delivery_tag, true), NackOutcome::Requeued { .. }));
        }
        assert_eq!(q.resident_bytes(), resident);
        // Page out, then purge: all counters return to zero and the purge
        // reports the paged locators for spill release.
        q.page_out_tail(0, 0, |m| spill_locator(m.body.len() as u32));
        let purged = q.purge();
        assert_eq!(purged.len(), 4);
        assert!(purged.iter().all(|(_, loc)| loc.is_some()));
        assert_eq!(q.resident_bytes(), 0);
        assert_eq!(q.paged_bytes(), 0);
        assert_eq!(q.paged_len(), 0);
    }

    #[test]
    fn prop_conservation_of_messages() {
        // Invariant: published = ready + unacked + acked + dropped +
        // expired + requeue-deliveries accounted via redelivery. We model a
        // random interleaving of operations and check conservation.
        run_prop("queue conservation", |rng: &Rng| {
            let mut q = Queue::new("q", QueueOptions::default(), None);
            let now = Instant::now();
            let mut next_id = 0u64;
            let mut next_tag = 0u64;
            let mut outstanding: Vec<u64> = Vec::new(); // delivery tags
            let mut acked = 0u64;
            let mut dropped = 0u64;
            for c in 0..rng.range(1, 4) {
                q.add_consumer(consumer(&format!("c{c}"), c as u64, rng.range(0, 3) as u32));
            }
            for _ in 0..rng.range(1, 200) {
                match rng.below(4) {
                    0 => {
                        put(&mut q, msg(next_id, rng.below(10) as u8), now);
                        next_id += 1;
                    }
                    1 => {
                        let assigned = q.assign(now, || {
                            next_tag += 1;
                            next_tag
                        });
                        outstanding.extend(assigned.iter().map(|a| a.delivery_tag));
                    }
                    2 => {
                        if !outstanding.is_empty() {
                            let i = rng.range(0, outstanding.len());
                            let tag = outstanding.swap_remove(i);
                            assert!(q.ack(tag).is_some());
                            acked += 1;
                        }
                    }
                    _ => {
                        if !outstanding.is_empty() {
                            let i = rng.range(0, outstanding.len());
                            let tag = outstanding.swap_remove(i);
                            let requeue = rng.chance(0.5);
                            match q.nack(tag, requeue) {
                                NackOutcome::Requeued { .. } => assert!(requeue),
                                NackOutcome::Dead(_) => {
                                    assert!(!requeue);
                                    dropped += 1;
                                }
                                NackOutcome::Unknown => panic!("live tag must be known"),
                            }
                        }
                    }
                }
                // Conservation: every published message is in exactly one
                // place.
                assert_eq!(
                    next_id,
                    (q.ready_len() + q.unacked_len()) as u64 + acked + dropped,
                    "conservation violated"
                );
                assert_eq!(q.unacked_len(), outstanding.len());
            }
        });
    }

    fn stream_queue(partitions: u32) -> Queue {
        Queue::new(
            "s",
            QueueOptions { stream: true, partitions, ..Default::default() },
            None,
        )
    }

    #[test]
    fn stream_exactly_one_member_per_group_by_partition() {
        let mut q = stream_queue(3);
        let now = Instant::now();
        for i in 0..9 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_stream_member("g", consumer("m0", 1, 0), Some(0));
        q.add_stream_member("g", consumer("m1", 2, 0), None);
        q.add_stream_member("g", consumer("m2", 3, 0), None);
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 9, "every entry delivered exactly once to the group");
        for x in &a {
            let offset = x.offset.expect("stream deliveries carry offsets");
            // Partition assignment: offset % partitions picks the member.
            let expect = format!("m{}", offset % 3);
            assert_eq!(x.consumer_tag, expect, "offset {offset} on the wrong member");
        }
        let mut offsets: Vec<u64> = a.iter().filter_map(|x| x.offset).collect();
        offsets.sort_unstable();
        assert_eq!(offsets, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn stream_groups_replay_independently() {
        let mut q = stream_queue(1);
        let now = Instant::now();
        for i in 0..5 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_stream_member("a", consumer("ca", 1, 0), Some(0));
        q.add_stream_member("b", consumer("cb", 2, 0), Some(0));
        let mut tags = tagger();
        let x = q.assign(now, &mut tags);
        assert_eq!(x.len(), 10, "each group reads the full log");
        assert_eq!(x.iter().filter(|d| d.consumer_tag == "ca").count(), 5);
        assert_eq!(x.iter().filter(|d| d.consumer_tag == "cb").count(), 5);
        // Ack group a fully; group b's cursor is untouched.
        for d in x.iter().filter(|d| d.consumer_tag == "ca") {
            assert!(q.ack(d.delivery_tag).is_some());
        }
        assert_eq!(q.stream_group_committed("a"), Some(5));
        assert_eq!(q.stream_group_committed("b"), Some(0));
    }

    #[test]
    fn stream_new_group_starts_at_tail_seek_rewinds() {
        let mut q = stream_queue(1);
        let now = Instant::now();
        for i in 0..4 {
            put(&mut q, msg(i, 0), now);
        }
        let mut tags = tagger();
        // Attach without seek: only entries published afterwards arrive.
        q.add_stream_member("live", consumer("cl", 1, 0), None);
        assert!(q.assign(now, &mut tags).is_empty());
        put(&mut q, msg(4, 0), now);
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].offset, Some(4));
        // Attach with seek 0: full replay from the beginning.
        q.add_stream_member("replay", consumer("cr", 2, 0), Some(0));
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 5, "seek 0 replays the whole log");
        assert_eq!(b[0].offset, Some(0));
    }

    #[test]
    fn stream_connection_death_redelivers_to_survivors() {
        let mut q = stream_queue(4);
        let now = Instant::now();
        for i in 0..8 {
            put(&mut q, msg(i, 0), now);
        }
        // First member seeks the (empty) group to 0; the second joins it.
        q.add_stream_member("g", consumer("dead", 7, 0), Some(0));
        q.add_stream_member("g", consumer("alive", 8, 0), None);
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 8);
        let dead_held: Vec<u64> =
            a.iter().filter(|x| x.connection == 7).filter_map(|x| x.offset).collect();
        assert!(!dead_held.is_empty());
        let out = q.drop_connection(7);
        assert_eq!(out.dead_tags.len(), dead_held.len());
        assert!(out.dead.is_empty(), "streams never dead-letter");
        // The survivor picks the offsets back up, marked redelivered.
        let b = q.assign(now, &mut tags);
        let mut redelivered: Vec<u64> = b.iter().filter_map(|x| x.offset).collect();
        redelivered.sort_unstable();
        let mut expected = dead_held.clone();
        expected.sort_unstable();
        assert_eq!(redelivered, expected);
        assert!(b.iter().all(|x| x.message.redelivered && x.connection == 8));
    }

    #[test]
    fn stream_out_of_order_acks_close_the_watermark() {
        let mut q = stream_queue(1);
        let now = Instant::now();
        for i in 0..3 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_stream_member("g", consumer("c", 1, 0), Some(0));
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 3);
        // Ack 2 then 1: watermark waits for the gap at 0.
        assert!(q.ack(a[2].delivery_tag).is_some());
        assert!(q.ack(a[1].delivery_tag).is_some());
        assert_eq!(q.stream_group_committed("g"), Some(0));
        // Ack 0: the contiguous prefix closes in one step.
        assert!(q.ack(a[0].delivery_tag).is_some());
        assert_eq!(q.stream_group_committed("g"), Some(3));
    }

    #[test]
    fn stream_nack_requeues_or_marks_consumed_never_dead() {
        let mut q = stream_queue(1);
        let now = Instant::now();
        put(&mut q, msg(0, 0), now);
        put(&mut q, msg(1, 0), now);
        q.add_stream_member("g", consumer("c", 1, 0), Some(0));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        // Requeue: the offset comes back marked redelivered.
        assert!(matches!(q.nack(a[0].delivery_tag, true), NackOutcome::Requeued { .. }));
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].offset, Some(0));
        assert!(b[0].message.redelivered);
        // Reject: consumed for this group (no dead-letter), watermark moves.
        assert!(matches!(q.nack(b[0].delivery_tag, false), NackOutcome::Requeued { .. }));
        assert!(q.ack(a[1].delivery_tag).is_some());
        assert_eq!(q.stream_group_committed("g"), Some(2));
        assert_eq!(q.dead_lettered, 0);
    }

    #[test]
    fn stream_head_of_line_stall_preserves_partition_order() {
        let mut q = stream_queue(1);
        let now = Instant::now();
        for i in 0..4 {
            put(&mut q, msg(i, 0), now);
        }
        // Single partition, prefetch 1: the owner must ack before the
        // next offset flows — the group never skips ahead.
        q.add_stream_member("g", consumer("c", 1, 1), Some(0));
        q.add_stream_member("g", consumer("idle", 2, 0), None);
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 1, "partition owner at capacity stalls the group");
        assert_eq!(a[0].consumer_tag, "c");
        assert!(q.assign(now, &mut tags).is_empty());
        assert!(q.ack(a[0].delivery_tag).is_some());
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].offset, Some(1));
    }

    #[test]
    fn stream_purge_resets_log_and_cursors() {
        let mut q = stream_queue(1);
        let now = Instant::now();
        for i in 0..6 {
            put(&mut q, msg(i, 0), now);
        }
        q.add_stream_member("g", consumer("c", 1, 0), Some(0));
        assert!(q.purge().is_empty(), "stream purge has nothing for the WAL to retire");
        assert_eq!(q.stream_base_offset(), 6);
        assert_eq!(q.stream_next_offset(), 6);
        assert_eq!(q.stream_group_committed("g"), Some(6), "cursors clamp forward");
        // Offsets keep counting after a purge; replay sees only new entries.
        put(&mut q, msg(6, 0), now);
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].offset, Some(6));
    }

    #[test]
    fn stream_ignores_work_queue_consumers_and_vice_versa() {
        let mut q = stream_queue(1);
        assert!(!q.add_consumer(consumer("c", 1, 0)), "plain consume refused on streams");
        assert!(q.add_stream_member("g", consumer("c", 1, 0), None));
        assert!(!q.add_stream_member("g2", consumer("c", 2, 0), None), "tag taken");
        assert!(q.has_consumer("c"));
        assert_eq!(q.consumer_count(), 1);
        assert_eq!(q.all_consumers().len(), 1);
        assert!(q.remove_consumer("c"));
        assert!(!q.has_consumer("c"));
        let mut wq = Queue::new("w", QueueOptions::default(), None);
        assert!(!wq.add_stream_member("g", consumer("c", 1, 0), None));
        assert!(!wq.stream_commit("g", 0));
        assert_eq!(wq.stream_retain(), 0);
    }

    #[test]
    fn prop_prefetch_never_exceeded() {
        run_prop("prefetch bound", |rng: &Rng| {
            let mut q = Queue::new("q", QueueOptions::default(), None);
            let now = Instant::now();
            let prefetch = rng.range(1, 5) as u32;
            q.add_consumer(consumer("c", 1, prefetch));
            let mut next_tag = 0u64;
            let mut outstanding = Vec::new();
            for i in 0..rng.range(1, 100) {
                put(&mut q, msg(i as u64, 0), now);
                if rng.chance(0.7) {
                    let a = q.assign(now, || {
                        next_tag += 1;
                        next_tag
                    });
                    outstanding.extend(a.into_iter().map(|x| x.delivery_tag));
                }
                if rng.chance(0.3) && !outstanding.is_empty() {
                    let tag = outstanding.remove(0);
                    q.ack(tag);
                }
                assert!(
                    q.unacked_len() <= prefetch as usize,
                    "unacked {} exceeds prefetch {prefetch}",
                    q.unacked_len()
                );
            }
        });
    }
}
