//! A single message queue: priority-laned ready list, unacked in-flight
//! tracking, consumer round-robin with prefetch accounting, TTL expiry.
//!
//! This module is pure data structure — no locks, no I/O — which is what
//! makes it property-testable. The [`super::shard`] module wraps a shard
//! lock around a subset of `Queue`s; [`super::core`] composes the shards.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use crate::broker::protocol::{EncodedProps, QueueOptions};
use crate::wire::{Bytes, Value};

/// Number of priority lanes (priorities 0–9).
pub const PRIORITY_LANES: usize = 10;

/// A message held by a queue. Every field that can be large is behind a
/// refcount (`Arc<str>` names, [`Bytes`] body, [`EncodedProps`]), so the
/// per-delivery / per-fanout-copy `clone()` is a handful of refcount bumps
/// — the payload is encoded once at the publisher and never duplicated.
#[derive(Clone, Debug)]
pub struct QueuedMessage {
    /// Broker-wide unique id (also the WAL record id for durable queues).
    pub msg_id: u64,
    pub exchange: Arc<str>,
    pub routing_key: Arc<str>,
    /// The publisher's encoded body — opaque to the broker.
    pub body: Bytes,
    pub props: EncodedProps,
    /// Instant after which the message is expired (from per-message or
    /// per-queue TTL).
    pub deadline: Option<Instant>,
    /// True once the message has been delivered at least once before.
    pub redelivered: bool,
}

impl QueuedMessage {
    fn lane(&self) -> usize {
        (self.props.priority as usize).min(PRIORITY_LANES - 1)
    }

    fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// A consumer attached to a queue.
#[derive(Clone, Debug)]
pub struct Consumer {
    pub consumer_tag: String,
    /// Owning connection (used to requeue on connection death).
    pub connection: u64,
    /// Max unacked deliveries outstanding; 0 = unlimited.
    pub prefetch: u32,
    /// Current unacked deliveries outstanding.
    pub in_flight: u32,
}

impl Consumer {
    fn has_capacity(&self) -> bool {
        self.prefetch == 0 || self.in_flight < self.prefetch
    }
}

/// A message handed to a consumer, not yet acknowledged.
#[derive(Clone, Debug)]
pub struct InFlight {
    pub message: QueuedMessage,
    pub consumer_tag: String,
    pub connection: u64,
}

/// A delivery decision produced by the queue (the core turns these into
/// wire messages).
#[derive(Clone, Debug)]
pub struct Assignment {
    pub consumer_tag: String,
    pub connection: u64,
    pub delivery_tag: u64,
    pub message: QueuedMessage,
}

/// The queue itself.
pub struct Queue {
    /// Interned name handle (shared with the router's interner and the
    /// shard map key — cloning it anywhere is a refcount bump).
    pub name: Arc<str>,
    pub options: QueueOptions,
    /// Declaring connection (for `exclusive`).
    pub owner: Option<u64>,
    /// Ready messages by priority lane; FIFO within a lane.
    ready: [VecDeque<QueuedMessage>; PRIORITY_LANES],
    ready_count: usize,
    /// Ready messages carrying a TTL deadline. When zero, the periodic
    /// expiry sweep skips this queue without scanning it.
    ttl_ready: usize,
    /// Lower bound on the earliest deadline among ready TTL'd messages
    /// (exact after a full sweep, conservative otherwise — popping a
    /// message never raises it). `Some` iff `ttl_ready > 0`.
    earliest_deadline: Option<Instant>,
    /// Delivered, awaiting ack, keyed by delivery tag.
    unacked: HashMap<u64, InFlight>,
    consumers: Vec<Consumer>,
    /// Round-robin cursor over `consumers`.
    rr_cursor: usize,
    /// Statistics (monotonic).
    pub published: u64,
    pub delivered: u64,
    pub acked: u64,
    pub requeued: u64,
    pub expired: u64,
    pub dropped_overflow: u64,
    /// Ids of expired messages encountered during assignment, buffered for
    /// the core to retire from the WAL (see `drain_expired_ids`).
    expired_ids: Vec<u64>,
}

impl Queue {
    pub fn new(name: impl Into<Arc<str>>, options: QueueOptions, owner: Option<u64>) -> Self {
        Queue {
            name: name.into(),
            options,
            owner,
            ready: Default::default(),
            ready_count: 0,
            ttl_ready: 0,
            earliest_deadline: None,
            unacked: HashMap::new(),
            consumers: Vec::new(),
            rr_cursor: 0,
            published: 0,
            delivered: 0,
            acked: 0,
            requeued: 0,
            expired: 0,
            dropped_overflow: 0,
            expired_ids: Vec::new(),
        }
    }

    pub fn ready_len(&self) -> usize {
        self.ready_count
    }

    pub fn unacked_len(&self) -> usize {
        self.unacked.len()
    }

    pub fn consumer_count(&self) -> usize {
        self.consumers.len()
    }

    pub fn has_consumer(&self, tag: &str) -> bool {
        self.consumers.iter().any(|c| c.consumer_tag == tag)
    }

    /// The attached consumers (the core uses this to notify owners when a
    /// queue is deleted out from under them).
    pub fn consumers(&self) -> &[Consumer] {
        &self.consumers
    }

    /// Enqueue a message. Applies the queue default TTL when the message
    /// has none, and enforces `max_length` by dropping the oldest ready
    /// message. Returns ids of messages dropped by overflow (for WAL acks).
    pub fn publish(&mut self, mut msg: QueuedMessage, now: Instant) -> Vec<u64> {
        if msg.deadline.is_none() {
            let ttl = msg.props.expiration_ms.or(self.options.default_ttl_ms);
            msg.deadline =
                ttl.map(|ms| now + std::time::Duration::from_millis(ms));
        }
        let mut dropped = Vec::new();
        if let Some(max) = self.options.max_length {
            while self.ready_count >= max.max(1) {
                if let Some(old) = self.pop_ready(now) {
                    self.dropped_overflow += 1;
                    dropped.push(old.msg_id);
                } else {
                    break;
                }
            }
        }
        self.track_ttl_in(msg.deadline);
        let lane = msg.lane();
        self.ready[lane].push_back(msg);
        self.ready_count += 1;
        self.published += 1;
        dropped
    }

    /// Bookkeeping when a deadline-carrying message enters a ready lane:
    /// maintains the earliest-deadline lower bound the sweep gates on.
    fn track_ttl_in(&mut self, deadline: Option<Instant>) {
        if let Some(d) = deadline {
            self.ttl_ready += 1;
            self.earliest_deadline = Some(self.earliest_deadline.map_or(d, |e| e.min(d)));
        }
    }

    /// Bookkeeping when a deadline-carrying message leaves a ready lane.
    /// The bound is not recomputed (it may now be earlier than any live
    /// deadline — a sweep then scans needlessly but never skips wrongly);
    /// it resets exactly when no TTL'd message remains.
    fn track_ttl_out(&mut self, deadline: Option<Instant>) {
        if deadline.is_some() {
            self.ttl_ready -= 1;
            if self.ttl_ready == 0 {
                self.earliest_deadline = None;
            }
        }
    }

    /// Ready messages currently carrying a TTL deadline (sweep-skip
    /// bookkeeping, exposed for tests).
    pub fn ttl_pending(&self) -> usize {
        self.ttl_ready
    }

    /// Pop the highest-priority, oldest ready message, discarding expired
    /// ones along the way (their ids are recorded in `expired`).
    fn pop_ready(&mut self, now: Instant) -> Option<QueuedMessage> {
        for lane in (0..PRIORITY_LANES).rev() {
            while let Some(msg) = self.ready[lane].pop_front() {
                self.ready_count -= 1;
                self.track_ttl_out(msg.deadline);
                if msg.expired(now) {
                    self.expired += 1;
                    self.expired_ids.push(msg.msg_id);
                    continue;
                }
                return Some(msg);
            }
        }
        None
    }

    /// Register a consumer. Fails (returns false) if the tag is taken.
    pub fn add_consumer(&mut self, consumer: Consumer) -> bool {
        if self.has_consumer(&consumer.consumer_tag) {
            return false;
        }
        self.consumers.push(consumer);
        true
    }

    /// Remove a consumer by tag. Returns true if it existed.
    pub fn remove_consumer(&mut self, tag: &str) -> bool {
        let before = self.consumers.len();
        self.consumers.retain(|c| c.consumer_tag != tag);
        if self.rr_cursor >= self.consumers.len() {
            self.rr_cursor = 0;
        }
        self.consumers.len() != before
    }

    /// Remove a consumer only if it is owned by `connection`. Used by
    /// rollback paths so they cannot tear down a same-tag consumer that a
    /// different (live) connection registered in the meantime.
    pub fn remove_consumer_of(&mut self, tag: &str, connection: u64) -> bool {
        let before = self.consumers.len();
        self.consumers.retain(|c| !(c.consumer_tag == tag && c.connection == connection));
        if self.rr_cursor >= self.consumers.len() {
            self.rr_cursor = 0;
        }
        self.consumers.len() != before
    }

    /// Drive delivery: assign ready messages to consumers with free
    /// prefetch capacity, round-robin. `next_tag` allocates delivery tags.
    ///
    /// This is the queue's core invariant enforcement point: a message is
    /// moved from `ready` to `unacked` *atomically* with the decision to
    /// hand it to exactly one consumer — the "no race conditions between
    /// multiple daemon processes" guarantee in the paper.
    pub fn assign(&mut self, now: Instant, next_tag: impl FnMut() -> u64) -> Vec<Assignment> {
        self.assign_up_to(now, usize::MAX, next_tag)
    }

    /// Like [`Queue::assign`] but hands out at most `limit` messages — the
    /// batched-dispatch entry point, bounding how long a shard lock is held
    /// per drain round.
    pub fn assign_up_to(
        &mut self,
        now: Instant,
        limit: usize,
        mut next_tag: impl FnMut() -> u64,
    ) -> Vec<Assignment> {
        let mut out = Vec::new();
        if self.consumers.is_empty() || limit == 0 {
            return out;
        }
        'outer: while self.ready_count > 0 && out.len() < limit {
            // Find the next consumer with capacity, starting at the cursor.
            let n = self.consumers.len();
            let mut found = None;
            for i in 0..n {
                let idx = (self.rr_cursor + i) % n;
                if self.consumers[idx].has_capacity() {
                    found = Some(idx);
                    break;
                }
            }
            let Some(idx) = found else { break 'outer };
            let Some(msg) = self.pop_ready(now) else { break 'outer };
            let tag = next_tag();
            let consumer = &mut self.consumers[idx];
            consumer.in_flight += 1;
            self.rr_cursor = (idx + 1) % n;
            self.delivered += 1;
            self.unacked.insert(
                tag,
                InFlight {
                    // Refcount bumps only: body/props/names are shared, so
                    // keeping the unacked copy costs no payload duplication.
                    message: msg.clone(),
                    consumer_tag: consumer.consumer_tag.clone(),
                    connection: consumer.connection,
                },
            );
            out.push(Assignment {
                consumer_tag: consumer.consumer_tag.clone(),
                connection: consumer.connection,
                delivery_tag: tag,
                message: msg,
            });
        }
        out
    }

    /// Acknowledge a delivery. Returns the message id for WAL retirement,
    /// or None if the tag is unknown (double-ack is idempotent).
    pub fn ack(&mut self, delivery_tag: u64) -> Option<u64> {
        let inflight = self.unacked.remove(&delivery_tag)?;
        if let Some(c) =
            self.consumers.iter_mut().find(|c| c.consumer_tag == inflight.consumer_tag)
        {
            c.in_flight = c.in_flight.saturating_sub(1);
        }
        self.acked += 1;
        Some(inflight.message.msg_id)
    }

    /// Negative-acknowledge. When `requeue`, the message returns to the
    /// front of its priority lane marked redelivered; otherwise it is
    /// dropped (dead-lettered out of existence). Returns the message id
    /// when the message was dropped (for WAL retirement).
    pub fn nack(&mut self, delivery_tag: u64, requeue: bool) -> Option<u64> {
        let inflight = self.unacked.remove(&delivery_tag)?;
        if let Some(c) =
            self.consumers.iter_mut().find(|c| c.consumer_tag == inflight.consumer_tag)
        {
            c.in_flight = c.in_flight.saturating_sub(1);
        }
        if requeue {
            let mut msg = inflight.message;
            msg.redelivered = true;
            self.track_ttl_in(msg.deadline);
            let lane = msg.lane();
            self.ready[lane].push_front(msg);
            self.ready_count += 1;
            self.requeued += 1;
            None
        } else {
            Some(inflight.message.msg_id)
        }
    }

    /// Requeue every unacked message belonging to `connection` and remove
    /// its consumers — what the broker does when a client dies (abrupt
    /// shutdown, two missed heartbeats). Returns the now-dead delivery tags
    /// so the caller can prune its delivery index (requeued messages get
    /// fresh tags on redelivery).
    ///
    /// Requeued messages are re-inserted at the *front* of their priority
    /// lane in ascending delivery-tag order, so a batch taken in order
    /// `m1, m2, m3` comes back as `m1, m2, m3` — redelivery preserves the
    /// original FIFO order.
    pub fn drop_connection(&mut self, connection: u64) -> Vec<u64> {
        let mut tags: Vec<u64> = self
            .unacked
            .iter()
            .filter(|(_, f)| f.connection == connection)
            .map(|(t, _)| *t)
            .collect();
        // Descending tag order + push_front = oldest delivery ends up first.
        tags.sort_unstable_by(|a, b| b.cmp(a));
        for tag in &tags {
            let inflight = self.unacked.remove(tag).unwrap();
            let mut msg = inflight.message;
            msg.redelivered = true;
            self.track_ttl_in(msg.deadline);
            let lane = msg.lane();
            self.ready[lane].push_front(msg);
            self.ready_count += 1;
            self.requeued += 1;
        }
        self.consumers.retain(|c| c.connection != connection);
        if self.rr_cursor >= self.consumers.len() {
            self.rr_cursor = 0;
        }
        tags
    }

    /// Drop all ready messages; returns their ids (for WAL retirement).
    pub fn purge(&mut self) -> Vec<u64> {
        let mut ids = Vec::with_capacity(self.ready_count);
        for lane in &mut self.ready {
            for m in lane.drain(..) {
                ids.push(m.msg_id);
            }
        }
        self.ready_count = 0;
        self.ttl_ready = 0;
        self.earliest_deadline = None;
        ids
    }

    /// Take the ids of messages that expired during assignment since the
    /// last call (the core retires them from the WAL).
    pub fn drain_expired_ids(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.expired_ids)
    }

    /// Remove expired ready messages (periodic sweep). Returns their ids.
    ///
    /// O(1) for the common case: when no ready message carries a TTL, or
    /// the earliest tracked deadline is still in the future, the scan is
    /// skipped entirely — a broker full of TTL-less queues pays nothing
    /// for the sweep. A scan recomputes the bound exactly.
    pub fn sweep_expired(&mut self, now: Instant) -> Vec<u64> {
        if self.ttl_ready == 0 {
            return Vec::new();
        }
        if let Some(earliest) = self.earliest_deadline {
            if now < earliest {
                return Vec::new();
            }
        }
        let mut ids = Vec::new();
        let mut remaining = 0usize;
        let mut earliest: Option<Instant> = None;
        for lane in &mut self.ready {
            lane.retain(|m| {
                if m.expired(now) {
                    ids.push(m.msg_id);
                    false
                } else {
                    if let Some(d) = m.deadline {
                        remaining += 1;
                        earliest = Some(earliest.map_or(d, |e| e.min(d)));
                    }
                    true
                }
            });
        }
        self.ready_count -= ids.len();
        self.expired += ids.len() as u64;
        self.ttl_ready = remaining;
        self.earliest_deadline = earliest;
        ids
    }

    /// All messages (ready + unacked) — used for durable-queue snapshots.
    pub fn all_messages(&self) -> Vec<&QueuedMessage> {
        let mut v: Vec<&QueuedMessage> = Vec::with_capacity(self.ready_count + self.unacked.len());
        for lane in (0..PRIORITY_LANES).rev() {
            v.extend(self.ready[lane].iter());
        }
        v.extend(self.unacked.values().map(|f| &f.message));
        v
    }

    /// Queue statistics as a wire value (answering `Status` requests).
    pub fn stats(&self) -> Value {
        Value::map([
            ("ready", Value::from(self.ready_len())),
            ("unacked", Value::from(self.unacked_len())),
            ("consumers", Value::from(self.consumer_count())),
            ("published", Value::from(self.published)),
            ("delivered", Value::from(self.delivered)),
            ("acked", Value::from(self.acked)),
            ("requeued", Value::from(self.requeued)),
            ("expired", Value::from(self.expired)),
            ("dropped_overflow", Value::from(self.dropped_overflow)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::MessageProps;
    use crate::proputil::{run_prop, Rng};
    use std::time::Duration;

    fn msg(id: u64, priority: u8) -> QueuedMessage {
        QueuedMessage {
            msg_id: id,
            exchange: "".into(),
            routing_key: "q".into(),
            body: Bytes::encode(&Value::I64(id as i64)),
            props: MessageProps { priority, ..Default::default() }.into(),
            deadline: None,
            redelivered: false,
        }
    }

    fn consumer(tag: &str, conn: u64, prefetch: u32) -> Consumer {
        Consumer { consumer_tag: tag.into(), connection: conn, prefetch, in_flight: 0 }
    }

    fn tagger() -> impl FnMut() -> u64 {
        let mut t = 0;
        move || {
            t += 1;
            t
        }
    }

    #[test]
    fn fifo_within_priority() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..5 {
            q.publish(msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        let ids: Vec<u64> = a.iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn higher_priority_first() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        q.publish(msg(1, 0), now);
        q.publish(msg(2, 9), now);
        q.publish(msg(3, 5), now);
        q.add_consumer(consumer("c1", 1, 0));
        let ids: Vec<u64> = q.assign(now, tagger()).iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn at_most_one_consumer_per_message() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..100 {
            q.publish(msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 0));
        q.add_consumer(consumer("c2", 2, 0));
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 100);
        // Every message delivered exactly once.
        let mut ids: Vec<u64> = a.iter().map(|x| x.message.msg_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..100).collect::<Vec<_>>());
        // Round-robin split.
        let c1 = a.iter().filter(|x| x.consumer_tag == "c1").count();
        assert_eq!(c1, 50);
    }

    #[test]
    fn prefetch_limits_in_flight() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..10 {
            q.publish(msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 1));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 1, "prefetch=1 allows a single in-flight message");
        assert_eq!(q.ready_len(), 9);
        assert_eq!(q.unacked_len(), 1);
        // Ack frees the slot; next assign delivers exactly one more.
        assert!(q.ack(a[0].delivery_tag).is_some());
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].message.msg_id, 1);
    }

    #[test]
    fn ack_is_idempotent() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        q.publish(msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        assert!(q.ack(a[0].delivery_tag).is_some());
        assert!(q.ack(a[0].delivery_tag).is_none());
        assert_eq!(q.acked, 1);
    }

    #[test]
    fn nack_requeue_preserves_message_marks_redelivered() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        q.publish(msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert!(!a[0].message.redelivered);
        q.nack(a[0].delivery_tag, true);
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert!(b[0].message.redelivered);
    }

    #[test]
    fn nack_drop_discards() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        q.publish(msg(0, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let a = q.assign(now, tagger());
        assert_eq!(q.nack(a[0].delivery_tag, false), Some(0));
        assert_eq!(q.ready_len(), 0);
        assert_eq!(q.unacked_len(), 0);
    }

    #[test]
    fn connection_death_requeues_all_unacked() {
        // The headline robustness property: abrupt consumer death loses
        // nothing.
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..10 {
            q.publish(msg(i, 0), now);
        }
        q.add_consumer(consumer("dead", 7, 0));
        let a = q.assign(now, tagger());
        assert_eq!(a.len(), 10);
        assert_eq!(q.drop_connection(7).len(), 10);
        assert_eq!(q.ready_len(), 10);
        assert_eq!(q.unacked_len(), 0);
        assert_eq!(q.consumer_count(), 0);
        // A new consumer picks everything up, marked redelivered, in the
        // original FIFO order.
        q.add_consumer(consumer("alive", 8, 0));
        let b = q.assign(now, tagger());
        assert_eq!(b.len(), 10);
        assert!(b.iter().all(|x| x.message.redelivered));
        let ids: Vec<u64> = b.iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>(), "redelivery must preserve order");
    }

    #[test]
    fn assign_up_to_bounds_batch_size() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..10 {
            q.publish(msg(i, 0), now);
        }
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign_up_to(now, 4, &mut tags);
        assert_eq!(a.len(), 4);
        assert_eq!(q.ready_len(), 6);
        let b = q.assign_up_to(now, 100, &mut tags);
        assert_eq!(b.len(), 6);
        assert_eq!(b[0].message.msg_id, 4, "batches drain in FIFO order");
    }

    #[test]
    fn expired_messages_not_delivered() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let mut m = msg(0, 0);
        m.props = MessageProps { expiration_ms: Some(10), ..Default::default() }.into();
        q.publish(m, now);
        q.publish(msg(1, 0), now);
        q.add_consumer(consumer("c1", 1, 0));
        let later = now + Duration::from_millis(50);
        let a = q.assign(later, tagger());
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].message.msg_id, 1);
        assert_eq!(q.expired, 1);
    }

    #[test]
    fn queue_default_ttl_applied() {
        let mut q = Queue::new(
            "q",
            QueueOptions { default_ttl_ms: Some(5), ..Default::default() },
            None,
        );
        let now = Instant::now();
        q.publish(msg(0, 0), now);
        let swept = q.sweep_expired(now + Duration::from_millis(20));
        assert_eq!(swept, vec![0]);
        assert_eq!(q.ready_len(), 0);
    }

    #[test]
    fn sweep_skip_bookkeeping_tracks_ttl_messages() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        // No TTLs anywhere: nothing pending, sweep is a no-op.
        q.publish(msg(0, 0), now);
        assert_eq!(q.ttl_pending(), 0);
        assert!(q.sweep_expired(now + Duration::from_secs(60)).is_empty());
        assert_eq!(q.ready_len(), 1);
        // A TTL'd message is tracked in...
        let mut m = msg(1, 0);
        m.props = MessageProps { expiration_ms: Some(10), ..Default::default() }.into();
        q.publish(m, now);
        assert_eq!(q.ttl_pending(), 1);
        // ...and the sweep gate stays closed before its deadline.
        assert!(q.sweep_expired(now).is_empty());
        assert_eq!(q.ready_len(), 2);
        // After the deadline, exactly the TTL'd message is swept and the
        // tracking resets.
        assert_eq!(q.sweep_expired(now + Duration::from_millis(50)), vec![1]);
        assert_eq!(q.ttl_pending(), 0);
        assert_eq!(q.ready_len(), 1);
    }

    #[test]
    fn sweep_skip_cleared_on_pop_restored_on_requeue() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let mut m = msg(0, 0);
        m.props = MessageProps { expiration_ms: Some(10_000), ..Default::default() }.into();
        q.publish(m, now);
        assert_eq!(q.ttl_pending(), 1);
        // Delivery pops it out of ready: no TTL'd ready message remains.
        q.add_consumer(consumer("c1", 1, 0));
        let mut tags = tagger();
        let a = q.assign(now, &mut tags);
        assert_eq!(a.len(), 1);
        assert_eq!(q.ttl_pending(), 0);
        // Requeue puts it (and its deadline) back under tracking.
        q.nack(a[0].delivery_tag, true);
        assert_eq!(q.ttl_pending(), 1);
        // Connection-death requeue is tracked too.
        let b = q.assign(now, &mut tags);
        assert_eq!(b.len(), 1);
        assert_eq!(q.ttl_pending(), 0);
        q.drop_connection(1);
        assert_eq!(q.ttl_pending(), 1);
        // Purge resets everything.
        q.purge();
        assert_eq!(q.ttl_pending(), 0);
        assert!(q.sweep_expired(now + Duration::from_secs(60)).is_empty());
    }

    #[test]
    fn sweep_bound_is_conservative_after_pop() {
        // Two TTL'd messages; pop the earlier one. The retained bound may
        // now be stale (earlier than any live deadline) — the sweep must
        // still expire correctly, never skip wrongly.
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        let mut early = msg(0, 0);
        early.props = MessageProps { expiration_ms: Some(10), ..Default::default() }.into();
        q.publish(early, now);
        let mut late = msg(1, 0);
        late.props = MessageProps { expiration_ms: Some(1000), ..Default::default() }.into();
        q.publish(late, now);
        q.add_consumer(consumer("c1", 1, 1));
        let a = q.assign(now, tagger()); // pops msg 0 (prefetch 1)
        assert_eq!(a[0].message.msg_id, 0);
        assert_eq!(q.ttl_pending(), 1);
        // Before either deadline: a scan may run (stale bound) but must
        // remove nothing; after msg 1's deadline it must expire it.
        assert!(q.sweep_expired(now).is_empty());
        assert_eq!(q.sweep_expired(now + Duration::from_secs(5)), vec![1]);
        assert_eq!(q.ttl_pending(), 0);
    }

    #[test]
    fn max_length_drops_oldest() {
        let mut q = Queue::new(
            "q",
            QueueOptions { max_length: Some(3), ..Default::default() },
            None,
        );
        let now = Instant::now();
        for i in 0..5 {
            q.publish(msg(i, 0), now);
        }
        assert_eq!(q.ready_len(), 3);
        assert_eq!(q.dropped_overflow, 2);
        q.add_consumer(consumer("c1", 1, 0));
        let ids: Vec<u64> = q.assign(now, tagger()).iter().map(|x| x.message.msg_id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn duplicate_consumer_tag_rejected() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        assert!(q.add_consumer(consumer("c1", 1, 0)));
        assert!(!q.add_consumer(consumer("c1", 2, 0)));
    }

    #[test]
    fn purge_returns_ids() {
        let mut q = Queue::new("q", QueueOptions::default(), None);
        let now = Instant::now();
        for i in 0..4 {
            q.publish(msg(i, (i % 2) as u8), now);
        }
        let mut ids = q.purge();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(q.ready_len(), 0);
    }

    #[test]
    fn prop_conservation_of_messages() {
        // Invariant: published = ready + unacked + acked + dropped +
        // expired + requeue-deliveries accounted via redelivery. We model a
        // random interleaving of operations and check conservation.
        run_prop("queue conservation", |rng: &Rng| {
            let mut q = Queue::new("q", QueueOptions::default(), None);
            let now = Instant::now();
            let mut next_id = 0u64;
            let mut next_tag = 0u64;
            let mut outstanding: Vec<u64> = Vec::new(); // delivery tags
            let mut acked = 0u64;
            let mut dropped = 0u64;
            for c in 0..rng.range(1, 4) {
                q.add_consumer(consumer(&format!("c{c}"), c as u64, rng.range(0, 3) as u32));
            }
            for _ in 0..rng.range(1, 200) {
                match rng.below(4) {
                    0 => {
                        q.publish(msg(next_id, rng.below(10) as u8), now);
                        next_id += 1;
                    }
                    1 => {
                        let assigned = q.assign(now, || {
                            next_tag += 1;
                            next_tag
                        });
                        outstanding.extend(assigned.iter().map(|a| a.delivery_tag));
                    }
                    2 => {
                        if !outstanding.is_empty() {
                            let i = rng.range(0, outstanding.len());
                            let tag = outstanding.swap_remove(i);
                            assert!(q.ack(tag).is_some());
                            acked += 1;
                        }
                    }
                    _ => {
                        if !outstanding.is_empty() {
                            let i = rng.range(0, outstanding.len());
                            let tag = outstanding.swap_remove(i);
                            let requeue = rng.chance(0.5);
                            let r = q.nack(tag, requeue);
                            if !requeue {
                                assert!(r.is_some());
                                dropped += 1;
                            }
                        }
                    }
                }
                // Conservation: every published message is in exactly one
                // place.
                assert_eq!(
                    next_id,
                    (q.ready_len() + q.unacked_len()) as u64 + acked + dropped,
                    "conservation violated"
                );
                assert_eq!(q.unacked_len(), outstanding.len());
            }
        });
    }

    #[test]
    fn prop_prefetch_never_exceeded() {
        run_prop("prefetch bound", |rng: &Rng| {
            let mut q = Queue::new("q", QueueOptions::default(), None);
            let now = Instant::now();
            let prefetch = rng.range(1, 5) as u32;
            q.add_consumer(consumer("c", 1, prefetch));
            let mut next_tag = 0u64;
            let mut outstanding = Vec::new();
            for i in 0..rng.range(1, 100) {
                q.publish(msg(i as u64, 0), now);
                if rng.chance(0.7) {
                    let a = q.assign(now, || {
                        next_tag += 1;
                        next_tag
                    });
                    outstanding.extend(a.into_iter().map(|x| x.delivery_tag));
                }
                if rng.chance(0.3) && !outstanding.is_empty() {
                    let tag = outstanding.remove(0);
                    q.ack(tag);
                }
                assert!(
                    q.unacked_len() <= prefetch as usize,
                    "unacked {} exceeds prefetch {prefetch}",
                    q.unacked_len()
                );
            }
        });
    }
}
