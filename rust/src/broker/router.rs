//! The control plane of the sharded broker: exchange declarations,
//! bindings and route resolution, behind read-mostly `RwLock`s.
//!
//! Publishes only ever take read locks here (route resolution), so
//! concurrent publishers to different queues proceed in parallel; binds,
//! unbinds and queue (un)registration — rare, control-plane operations —
//! take the write lock.
//!
//! ## Interning
//!
//! The router owns the canonical [`Arc<str>`] for every live queue name:
//! [`Router::register_queue`] interns the name at declare time, bindings
//! store clones of that handle, and [`Router::route`] hands back an
//! `Arc<[Arc<str>]>` of those same handles — the string allocated at
//! declare is the only one that ever exists, and a publish performs zero
//! `String` allocations to learn its targets.
//!
//! ## The route cache
//!
//! `(exchange, routing_key) → Arc<[Arc<str>]>`, in front of all three
//! exchange kinds and the default exchange. Every cached entry carries
//! the **generation** (an `Arc<AtomicU64>` shared with its exchange) it
//! was resolved under; binds, unbinds and queue deletion bump the
//! generation, so a hit validates itself with one atomic load — no lock
//! on the exchange tables, no rescan, no allocation. Entries resolve
//! their `(generation, targets)` snapshot under the same read lock, so a
//! racing bind either bumps before the snapshot (cache refills) or after
//! (the stored generation is already stale) — a stale route can never be
//! served as current. Capacity is bounded (`route_cache_cap`); at
//! capacity the cache is flushed wholesale (rare, self-refilling). A cap
//! of 0 disables caching entirely, restoring seed behaviour.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::broker::exchange::Exchange;
use crate::broker::protocol::ExchangeKind;
use crate::error::{Error, Result};
use crate::metrics::Counter;

/// Default route-cache capacity (entries across all exchanges).
pub const DEFAULT_ROUTE_CACHE_CAP: usize = 4096;

/// A resolved route: refcounted slice of interned queue-name handles.
/// Cloning is one refcount bump; a cache hit returns the same allocation
/// every time (pinned by `Arc::ptr_eq` tests).
pub type RouteTargets = Arc<[Arc<str>]>;

/// One cached route with the generation snapshot it was resolved under.
struct CacheEntry {
    generation: Arc<AtomicU64>,
    seen: u64,
    targets: RouteTargets,
}

impl CacheEntry {
    fn live(&self) -> bool {
        self.generation.load(Ordering::Acquire) == self.seen
    }
}

/// Nested so a lookup needs no key allocation: exchange → routing key →
/// entry (a flat `(String, String)` key cannot be probed with borrowed
/// `&str`s).
#[derive(Default)]
struct CacheMap {
    by_exchange: HashMap<String, HashMap<String, CacheEntry>>,
    len: usize,
}

/// Max lock stripes for the cache map. Misses (fills) take one stripe's
/// write lock instead of a single global one, so publishers with low key
/// locality don't re-serialize on the cache the way the seed serialized
/// on its broker mutex; a capacity flush empties one stripe, not the
/// whole cache.
const CACHE_STRIPES: usize = 16;

struct RouteCache {
    /// Per-stripe entry budget (total cap ÷ stripe count).
    stripe_cap: usize,
    enabled: bool,
    stripes: Vec<RwLock<CacheMap>>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
}

impl RouteCache {
    fn new(cap: usize, hits: Arc<Counter>, misses: Arc<Counter>) -> Self {
        // Small caps get fewer stripes so the configured bound holds
        // exactly: stripes ≤ cap, and floor division means the live total
        // never exceeds `cap`.
        let nstripes = cap.clamp(1, CACHE_STRIPES);
        RouteCache {
            stripe_cap: cap / nstripes,
            enabled: cap > 0,
            stripes: (0..nstripes).map(|_| RwLock::new(CacheMap::default())).collect(),
            hits,
            misses,
        }
    }

    fn stripe(&self, exchange: &str, routing_key: &str) -> &RwLock<CacheMap> {
        let mut h = DefaultHasher::new();
        exchange.hash(&mut h);
        routing_key.hash(&mut h);
        &self.stripes[(h.finish() % self.stripes.len() as u64) as usize]
    }

    fn lookup(&self, exchange: &str, routing_key: &str) -> Option<RouteTargets> {
        let map = self.stripe(exchange, routing_key).read().unwrap();
        let entry = map.by_exchange.get(exchange)?.get(routing_key)?;
        if entry.live() {
            Some(Arc::clone(&entry.targets))
        } else {
            None
        }
    }

    fn insert(&self, exchange: &str, routing_key: &str, entry: CacheEntry) {
        let mut map = self.stripe(exchange, routing_key).write().unwrap();
        if map.len >= self.stripe_cap {
            // Stripe full: reclaim generation-stale entries first, so one
            // exchange's bind/unbind churn cannot evict other exchanges'
            // hot live routes that happen to share the stripe.
            let mut live = 0usize;
            map.by_exchange.retain(|_, inner| {
                inner.retain(|_, e| e.live());
                live += inner.len();
                !inner.is_empty()
            });
            map.len = live;
            if map.len >= self.stripe_cap {
                // Still full of live routes: flush wholesale. Rare (a
                // stripe's worth of distinct hot keys), cheap, strictly
                // safe — every dropped entry refills on demand.
                map.by_exchange.clear();
                map.len = 0;
            }
        }
        let inner = map.by_exchange.entry(exchange.to_string()).or_default();
        if inner.insert(routing_key.to_string(), entry).is_none() {
            map.len += 1;
        }
    }

    fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.read().unwrap().len).sum()
    }
}

/// Exchange/binding tables + the set of live queue names (the default
/// exchange routes on bare queue names, so existence lives here too).
pub struct Router {
    exchanges: RwLock<HashMap<String, Exchange>>,
    /// Interner + existence set: the canonical `Arc<str>` per live queue.
    queue_names: RwLock<HashSet<Arc<str>>>,
    /// Generation of the default exchange (bumped on queue register /
    /// unregister, which are its bind/unbind equivalents).
    default_generation: Arc<AtomicU64>,
    cache: RouteCache,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    /// A router with the default cache capacity and detached counters
    /// (tests / embedding without a metrics registry).
    pub fn new() -> Self {
        Self::with_cache(
            DEFAULT_ROUTE_CACHE_CAP,
            Arc::new(Counter::new()),
            Arc::new(Counter::new()),
        )
    }

    /// Full control: cache capacity (0 disables) and the hit/miss
    /// counters to book into (the broker wires these to
    /// `broker.route_cache_hits_total` / `..misses_total`).
    pub fn with_cache(cap: usize, hits: Arc<Counter>, misses: Arc<Counter>) -> Self {
        Router {
            exchanges: RwLock::new(HashMap::new()),
            queue_names: RwLock::new(HashSet::new()),
            default_generation: Arc::new(AtomicU64::new(0)),
            cache: RouteCache::new(cap, hits, misses),
        }
    }

    /// Record that a queue exists (declare). Idempotent. Returns the
    /// interned name handle — the one allocation of this queue name's
    /// lifetime; the shard map, the `Queue` and every binding share it.
    pub fn register_queue(&self, name: &str) -> Arc<str> {
        if let Some(existing) = self.interned(name) {
            return existing;
        }
        // Not interned yet: materialize the Arc and adopt it (a racing
        // register of the same name is resolved inside the write lock).
        self.register_queue_arc(Arc::from(name))
    }

    /// Like [`Router::register_queue`] but adopts an existing handle, so
    /// callers that already created the `Arc` (queue construction) intern
    /// that exact allocation.
    pub fn register_queue_arc(&self, name: Arc<str>) -> Arc<str> {
        let mut names = self.queue_names.write().unwrap();
        if let Some(existing) = names.get(&*name) {
            return Arc::clone(existing);
        }
        names.insert(Arc::clone(&name));
        self.default_generation.fetch_add(1, Ordering::Release);
        name
    }

    /// The interned handle for a live queue name, if any.
    pub fn interned(&self, name: &str) -> Option<Arc<str>> {
        self.queue_names.read().unwrap().get(name).cloned()
    }

    /// Record that a queue is gone (delete) and drop all its bindings.
    pub fn unregister_queue(&self, name: &str) {
        if self.queue_names.write().unwrap().remove(name) {
            self.default_generation.fetch_add(1, Ordering::Release);
        }
        for ex in self.exchanges.write().unwrap().values_mut() {
            // `unbind_queue` bumps the exchange generation only when it
            // actually removed bindings — untouched exchanges keep their
            // cached routes.
            ex.unbind_queue(name);
        }
    }

    pub fn queue_exists(&self, name: &str) -> bool {
        self.queue_names.read().unwrap().contains(name)
    }

    /// Declare an exchange. Redeclaring with the same kind is idempotent;
    /// with a different kind it is an error (AMQP behaviour).
    pub fn declare_exchange(&self, exchange: &str, kind: ExchangeKind) -> Result<()> {
        if exchange.is_empty() {
            return Err(Error::Broker("cannot declare the default exchange".into()));
        }
        let mut exchanges = self.exchanges.write().unwrap();
        match exchanges.get(exchange) {
            Some(ex) if ex.kind != kind => Err(Error::Broker(format!(
                "exchange '{exchange}' exists with kind {}",
                ex.kind.as_str()
            ))),
            Some(_) => Ok(()),
            None => {
                exchanges.insert(exchange.to_string(), Exchange::new(exchange, kind));
                Ok(())
            }
        }
    }

    pub fn bind(&self, exchange: &str, queue: &str, routing_key: &str) -> Result<()> {
        // The existence check happens *inside* the exchanges write lock so a
        // concurrent queue deletion cannot interleave between check and
        // insert: `unregister_queue` removes the name first, then takes this
        // same write lock to strip bindings — so either our binding lands
        // before the strip (and is stripped) or the name is already gone
        // (and we error). No stale binding can survive.
        let mut exchanges = self.exchanges.write().unwrap();
        let Some(interned) = self.interned(queue) else {
            return Err(Error::Broker(format!("no such queue '{queue}'")));
        };
        let ex = exchanges
            .get_mut(exchange)
            .ok_or_else(|| Error::Broker(format!("no such exchange '{exchange}'")))?;
        ex.bind(routing_key, &interned);
        Ok(())
    }

    pub fn unbind(&self, exchange: &str, queue: &str, routing_key: &str) -> Result<()> {
        let mut exchanges = self.exchanges.write().unwrap();
        let ex = exchanges
            .get_mut(exchange)
            .ok_or_else(|| Error::Broker(format!("no such exchange '{exchange}'")))?;
        ex.unbind(routing_key, queue);
        Ok(())
    }

    /// Resolve `(exchange, routing_key)` to target queue names. The empty
    /// exchange is the AMQP default exchange: direct to the queue named by
    /// the key, if it exists.
    ///
    /// A cache hit is the publish fast path: one read lock on the cache
    /// map, one atomic generation load, one refcount bump — no exchange
    /// table lock and **zero allocations** (consecutive hits return the
    /// same `Arc` allocation).
    pub fn route(&self, exchange: &str, routing_key: &str) -> Result<RouteTargets> {
        if self.cache.enabled {
            if let Some(targets) = self.cache.lookup(exchange, routing_key) {
                self.cache.hits.inc();
                return Ok(targets);
            }
            self.cache.misses.inc();
        }
        let entry = self.resolve(exchange, routing_key)?;
        let targets = Arc::clone(&entry.targets);
        if self.cache.enabled {
            self.cache.insert(exchange, routing_key, entry);
        }
        Ok(targets)
    }

    /// Resolve like [`Router::route`] (same cache, same fast path) but
    /// treat a missing exchange as "no targets" instead of an error — the
    /// dead-letter pipeline uses this so a misconfigured DLX drops the
    /// message (with a warning and a counter) rather than failing the
    /// ack/nack/sweep that triggered the death.
    pub fn route_if_exists(&self, exchange: &str, routing_key: &str) -> Option<RouteTargets> {
        self.route(exchange, routing_key).ok()
    }

    /// Resolve against the live tables, snapshotting `(generation,
    /// targets)` under one read-lock hold so the pair is consistent: a
    /// concurrent bind serialises on the write lock, so it either lands
    /// before our snapshot (we see its effect *and* its generation) or
    /// after (its bump invalidates what we are about to cache).
    fn resolve(&self, exchange: &str, routing_key: &str) -> Result<CacheEntry> {
        if exchange.is_empty() {
            let names = self.queue_names.read().unwrap();
            let seen = self.default_generation.load(Ordering::Acquire);
            let targets: RouteTargets = match names.get(routing_key) {
                Some(q) => Arc::from(vec![Arc::clone(q)]),
                None => Arc::from(Vec::new()),
            };
            return Ok(CacheEntry {
                generation: Arc::clone(&self.default_generation),
                seen,
                targets,
            });
        }
        let exchanges = self.exchanges.read().unwrap();
        let ex = exchanges
            .get(exchange)
            .ok_or_else(|| Error::Broker(format!("no such exchange '{exchange}'")))?;
        let generation = ex.generation();
        let seen = generation.load(Ordering::Acquire);
        let targets: RouteTargets = Arc::from(ex.route(routing_key));
        Ok(CacheEntry { generation, seen, targets })
    }

    pub fn exchange_count(&self) -> usize {
        self.exchanges.read().unwrap().len()
    }

    /// Cached entries across all stripes — live plus generation-stale
    /// ones not yet reclaimed by a stripe sweep (tests / diagnostics).
    pub fn route_cache_len(&self) -> usize {
        if self.cache.enabled {
            self.cache.len()
        } else {
            0
        }
    }

    pub fn route_cache_hits(&self) -> u64 {
        self.cache.hits.get()
    }

    pub fn route_cache_misses(&self) -> u64 {
        self.cache.misses.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(targets: &RouteTargets) -> Vec<String> {
        targets.iter().map(|q| q.to_string()).collect()
    }

    #[test]
    fn default_exchange_routes_to_existing_queue_only() {
        let r = Router::new();
        assert!(r.route("", "tasks").unwrap().is_empty());
        r.register_queue("tasks");
        assert_eq!(strs(&r.route("", "tasks").unwrap()), vec!["tasks"]);
        r.unregister_queue("tasks");
        assert!(r.route("", "tasks").unwrap().is_empty());
    }

    #[test]
    fn declare_is_idempotent_kind_conflict_rejected() {
        let r = Router::new();
        r.declare_exchange("x", ExchangeKind::Direct).unwrap();
        r.declare_exchange("x", ExchangeKind::Direct).unwrap();
        assert!(r.declare_exchange("x", ExchangeKind::Fanout).is_err());
        assert!(r.declare_exchange("", ExchangeKind::Direct).is_err());
        assert_eq!(r.exchange_count(), 1);
    }

    #[test]
    fn bind_requires_queue_and_exchange() {
        let r = Router::new();
        r.declare_exchange("x", ExchangeKind::Direct).unwrap();
        assert!(r.bind("x", "missing", "k").is_err());
        r.register_queue("q");
        assert!(r.bind("nope", "q", "k").is_err());
        r.bind("x", "q", "k").unwrap();
        assert_eq!(strs(&r.route("x", "k").unwrap()), vec!["q"]);
    }

    #[test]
    fn unregister_queue_drops_bindings_everywhere() {
        let r = Router::new();
        r.declare_exchange("a", ExchangeKind::Fanout).unwrap();
        r.declare_exchange("b", ExchangeKind::Topic).unwrap();
        r.register_queue("q");
        r.bind("a", "q", "").unwrap();
        r.bind("b", "q", "ev.#").unwrap();
        r.unregister_queue("q");
        assert!(r.route("a", "x").unwrap().is_empty());
        assert!(r.route("b", "ev.1").unwrap().is_empty());
    }

    #[test]
    fn route_to_unknown_exchange_is_error() {
        let r = Router::new();
        assert!(r.route("ghost", "k").is_err());
    }

    #[test]
    fn cache_hit_returns_the_same_allocation() {
        // The zero-allocation pin: consecutive cached routes are the SAME
        // Arc slice, not equal copies.
        let r = Router::new();
        r.declare_exchange("t", ExchangeKind::Topic).unwrap();
        r.register_queue("q1");
        r.bind("t", "q1", "proc.*.done").unwrap();
        let first = r.route("t", "proc.7.done").unwrap();
        let second = r.route("t", "proc.7.done").unwrap();
        assert!(Arc::ptr_eq(&first, &second), "cache hit must reuse the allocation");
        assert_eq!(r.route_cache_hits(), 1);
        assert_eq!(r.route_cache_misses(), 1);
        // The names inside are the interned declare-time handles.
        let interned = r.interned("q1").unwrap();
        assert!(Arc::ptr_eq(&first[0], &interned));
    }

    #[test]
    fn bind_invalidates_cached_route() {
        let r = Router::new();
        r.declare_exchange("t", ExchangeKind::Topic).unwrap();
        r.register_queue("q1");
        r.bind("t", "q1", "ev.#").unwrap();
        assert_eq!(strs(&r.route("t", "ev.x").unwrap()), vec!["q1"]);
        r.register_queue("q2");
        r.bind("t", "q2", "ev.*").unwrap();
        let mut got = strs(&r.route("t", "ev.x").unwrap());
        got.sort_unstable();
        assert_eq!(got, vec!["q1", "q2"], "cached route must refresh after bind");
    }

    #[test]
    fn unbind_and_queue_delete_invalidate_cached_route() {
        let r = Router::new();
        r.declare_exchange("t", ExchangeKind::Topic).unwrap();
        r.register_queue("q1");
        r.register_queue("q2");
        r.bind("t", "q1", "ev.#").unwrap();
        r.bind("t", "q2", "ev.#").unwrap();
        let mut got = strs(&r.route("t", "ev.a").unwrap());
        got.sort_unstable();
        assert_eq!(got, vec!["q1", "q2"]);
        r.unbind("t", "q1", "ev.#").unwrap();
        assert_eq!(strs(&r.route("t", "ev.a").unwrap()), vec!["q2"]);
        r.unregister_queue("q2");
        assert!(r.route("t", "ev.a").unwrap().is_empty());
    }

    #[test]
    fn default_exchange_cache_tracks_registration() {
        let r = Router::new();
        assert!(r.route("", "q").unwrap().is_empty());
        r.register_queue("q");
        assert_eq!(strs(&r.route("", "q").unwrap()), vec!["q"]);
        r.unregister_queue("q");
        assert!(r.route("", "q").unwrap().is_empty());
    }

    #[test]
    fn cap_zero_disables_caching() {
        let r = Router::with_cache(0, Arc::new(Counter::new()), Arc::new(Counter::new()));
        r.register_queue("q");
        let a = r.route("", "q").unwrap();
        let b = r.route("", "q").unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "cap 0 must resolve fresh every time");
        assert_eq!(r.route_cache_hits(), 0);
        assert_eq!(r.route_cache_misses(), 0);
        assert_eq!(r.route_cache_len(), 0);
    }

    #[test]
    fn cache_flushes_at_capacity() {
        // The configured cap bounds the cached total exactly (stripe
        // count adapts: stripes ≤ cap and floor division never inflate
        // the budget), including tiny caps below the stripe count.
        for cap in [4usize, 32] {
            let r =
                Router::with_cache(cap, Arc::new(Counter::new()), Arc::new(Counter::new()));
            r.register_queue("q");
            for i in 0..500 {
                r.route("", &format!("k{i}")).unwrap();
            }
            assert!(
                r.route_cache_len() <= cap,
                "cache exceeded cap {cap}: {}",
                r.route_cache_len()
            );
            // Still correct after stripe flushes.
            assert_eq!(strs(&r.route("", "q").unwrap()), vec!["q"]);
        }
    }

    #[test]
    fn stale_entries_reclaimed_before_live_ones_are_flushed() {
        // Fill a small cache, invalidate everything via a generation bump
        // (register bumps the default exchange), then keep inserting:
        // stale entries must be swept out rather than forcing wholesale
        // flushes, and the total stays bounded.
        let r = Router::with_cache(8, Arc::new(Counter::new()), Arc::new(Counter::new()));
        r.register_queue("q");
        for i in 0..8 {
            r.route("", &format!("a{i}")).unwrap();
        }
        r.register_queue("bump"); // invalidates every cached default route
        for i in 0..8 {
            r.route("", &format!("b{i}")).unwrap();
        }
        assert!(r.route_cache_len() <= 8, "stale entries must not inflate the cache");
        assert_eq!(strs(&r.route("", "q").unwrap()), vec!["q"]);
    }

    #[test]
    fn interning_is_idempotent() {
        let r = Router::new();
        let a = r.register_queue("q");
        let b = r.register_queue("q");
        assert!(Arc::ptr_eq(&a, &b), "re-register must return the interned handle");
        let c = r.register_queue_arc(Arc::from("q"));
        assert!(Arc::ptr_eq(&a, &c), "adopting a duplicate must return the original");
        let d: Arc<str> = Arc::from("fresh");
        let e = r.register_queue_arc(Arc::clone(&d));
        assert!(Arc::ptr_eq(&d, &e), "a new handle is adopted as-is");
    }
}
