//! The control plane of the sharded broker: exchange declarations,
//! bindings and route resolution, behind read-mostly `RwLock`s.
//!
//! Publishes only ever take read locks here (route resolution), so
//! concurrent publishers to different queues proceed in parallel; binds,
//! unbinds and queue (un)registration — rare, control-plane operations —
//! take the write lock.

use std::collections::{HashMap, HashSet};
use std::sync::RwLock;

use crate::broker::exchange::Exchange;
use crate::broker::protocol::ExchangeKind;
use crate::error::{Error, Result};

/// Exchange/binding tables + the set of live queue names (the default
/// exchange routes on bare queue names, so existence lives here too).
#[derive(Default)]
pub struct Router {
    exchanges: RwLock<HashMap<String, Exchange>>,
    queue_names: RwLock<HashSet<String>>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that a queue exists (declare). Idempotent.
    pub fn register_queue(&self, name: &str) {
        self.queue_names.write().unwrap().insert(name.to_string());
    }

    /// Record that a queue is gone (delete) and drop all its bindings.
    pub fn unregister_queue(&self, name: &str) {
        self.queue_names.write().unwrap().remove(name);
        for ex in self.exchanges.write().unwrap().values_mut() {
            ex.unbind_queue(name);
        }
    }

    pub fn queue_exists(&self, name: &str) -> bool {
        self.queue_names.read().unwrap().contains(name)
    }

    /// Declare an exchange. Redeclaring with the same kind is idempotent;
    /// with a different kind it is an error (AMQP behaviour).
    pub fn declare_exchange(&self, exchange: &str, kind: ExchangeKind) -> Result<()> {
        if exchange.is_empty() {
            return Err(Error::Broker("cannot declare the default exchange".into()));
        }
        let mut exchanges = self.exchanges.write().unwrap();
        match exchanges.get(exchange) {
            Some(ex) if ex.kind != kind => Err(Error::Broker(format!(
                "exchange '{exchange}' exists with kind {}",
                ex.kind.as_str()
            ))),
            Some(_) => Ok(()),
            None => {
                exchanges.insert(exchange.to_string(), Exchange::new(exchange, kind));
                Ok(())
            }
        }
    }

    pub fn bind(&self, exchange: &str, queue: &str, routing_key: &str) -> Result<()> {
        // The existence check happens *inside* the exchanges write lock so a
        // concurrent queue deletion cannot interleave between check and
        // insert: `unregister_queue` removes the name first, then takes this
        // same write lock to strip bindings — so either our binding lands
        // before the strip (and is stripped) or the name is already gone
        // (and we error). No stale binding can survive.
        let mut exchanges = self.exchanges.write().unwrap();
        if !self.queue_exists(queue) {
            return Err(Error::Broker(format!("no such queue '{queue}'")));
        }
        let ex = exchanges
            .get_mut(exchange)
            .ok_or_else(|| Error::Broker(format!("no such exchange '{exchange}'")))?;
        ex.bind(routing_key, queue);
        Ok(())
    }

    pub fn unbind(&self, exchange: &str, queue: &str, routing_key: &str) -> Result<()> {
        let mut exchanges = self.exchanges.write().unwrap();
        let ex = exchanges
            .get_mut(exchange)
            .ok_or_else(|| Error::Broker(format!("no such exchange '{exchange}'")))?;
        ex.unbind(routing_key, queue);
        Ok(())
    }

    /// Resolve `(exchange, routing_key)` to target queue names. The empty
    /// exchange is the AMQP default exchange: direct to the queue named by
    /// the key, if it exists.
    pub fn route(&self, exchange: &str, routing_key: &str) -> Result<Vec<String>> {
        if exchange.is_empty() {
            return Ok(if self.queue_exists(routing_key) {
                vec![routing_key.to_string()]
            } else {
                vec![]
            });
        }
        let exchanges = self.exchanges.read().unwrap();
        let ex = exchanges
            .get(exchange)
            .ok_or_else(|| Error::Broker(format!("no such exchange '{exchange}'")))?;
        Ok(ex.route(routing_key).into_iter().map(String::from).collect())
    }

    pub fn exchange_count(&self) -> usize {
        self.exchanges.read().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_exchange_routes_to_existing_queue_only() {
        let r = Router::new();
        assert!(r.route("", "tasks").unwrap().is_empty());
        r.register_queue("tasks");
        assert_eq!(r.route("", "tasks").unwrap(), vec!["tasks"]);
        r.unregister_queue("tasks");
        assert!(r.route("", "tasks").unwrap().is_empty());
    }

    #[test]
    fn declare_is_idempotent_kind_conflict_rejected() {
        let r = Router::new();
        r.declare_exchange("x", ExchangeKind::Direct).unwrap();
        r.declare_exchange("x", ExchangeKind::Direct).unwrap();
        assert!(r.declare_exchange("x", ExchangeKind::Fanout).is_err());
        assert!(r.declare_exchange("", ExchangeKind::Direct).is_err());
        assert_eq!(r.exchange_count(), 1);
    }

    #[test]
    fn bind_requires_queue_and_exchange() {
        let r = Router::new();
        r.declare_exchange("x", ExchangeKind::Direct).unwrap();
        assert!(r.bind("x", "missing", "k").is_err());
        r.register_queue("q");
        assert!(r.bind("nope", "q", "k").is_err());
        r.bind("x", "q", "k").unwrap();
        assert_eq!(r.route("x", "k").unwrap(), vec!["q"]);
    }

    #[test]
    fn unregister_queue_drops_bindings_everywhere() {
        let r = Router::new();
        r.declare_exchange("a", ExchangeKind::Fanout).unwrap();
        r.declare_exchange("b", ExchangeKind::Topic).unwrap();
        r.register_queue("q");
        r.bind("a", "q", "").unwrap();
        r.bind("b", "q", "ev.#").unwrap();
        r.unregister_queue("q");
        assert!(r.route("a", "x").unwrap().is_empty());
        assert!(r.route("b", "ev.1").unwrap().is_empty());
    }

    #[test]
    fn route_to_unknown_exchange_is_error() {
        let r = Router::new();
        assert!(r.route("ghost", "k").is_err());
    }
}
