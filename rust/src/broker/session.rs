//! One broker-side session: the per-connection protocol state machine,
//! plus the blocking [`Link`] driver used by the thread-per-connection
//! path and the inproc broker.
//!
//! [`SessionState`] is transport-free: it owns the broker-side
//! `ConnectionId` and turns incoming frames into broker calls. The epoll
//! reactor (`broker::reactor`) drives it from one event loop with no
//! per-session threads; [`serve_link`] drives it the historical way — the
//! caller's thread reads frames and a writer thread serialises everything
//! going the other way (replies, deliveries, consumer cancellations,
//! server heartbeats) so a slow reader on the far side never blocks broker
//! internals.
//!
//! The writer coalesces: after blocking for one message it drains whatever
//! else is already queued (bounded) and ships the lot via
//! [`Link::send_batch`] — one flush/syscall per burst instead of one per
//! message, which is where high-volume delivery throughput comes from.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use crate::broker::core::{BrokerHandle, ConnectionId, Outbound};
use crate::broker::protocol::{ClientRequest, ServerMsg};
use crate::error::Error;
use crate::transport::Link;
use crate::wire::{Frame, FrameType};

/// Max frames coalesced into one write unit by the session writer.
const WRITE_COALESCE_MAX: usize = 64;

/// What the session should do after a frame was handled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameOutcome {
    /// Keep reading.
    Continue,
    /// Orderly end of session (Goodbye, `Close`, or protocol corruption):
    /// flush pending output, then tear the connection down.
    End,
}

/// The transport-free half of a broker session: one registered broker
/// connection plus the frame-to-request state machine. Both the blocking
/// [`serve_link`] driver and the epoll reactor feed it frames; neither
/// owns any protocol logic of its own.
pub struct SessionState {
    conn: ConnectionId,
    /// Heartbeat interval negotiated by Hello (0 = none). Shared with
    /// whoever emits server->client heartbeats (writer thread / reactor),
    /// which sends at half this.
    heartbeat_ms: Arc<AtomicU64>,
}

impl SessionState {
    /// Register a broker connection whose server messages flow into
    /// `outbound`.
    pub fn open(broker: &BrokerHandle, outbound: Outbound) -> SessionState {
        let conn = broker.connect_with_outbound("<pre-hello>", 0, outbound);
        SessionState { conn, heartbeat_ms: Arc::new(AtomicU64::new(0)) }
    }

    /// The broker-side connection id.
    pub fn conn(&self) -> ConnectionId {
        self.conn
    }

    /// Negotiated heartbeat interval in ms (0 until Hello, or when the
    /// client opted out).
    pub fn heartbeat_ms(&self) -> u64 {
        self.heartbeat_ms.load(Ordering::Relaxed)
    }

    /// Shared handle to the negotiated interval (for writer threads).
    pub(crate) fn heartbeat_handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.heartbeat_ms)
    }

    /// Feed one received frame through the protocol state machine.
    /// Replies are pushed into the connection's outbound by the broker
    /// itself, guaranteeing the reply precedes any deliveries the request
    /// triggers.
    pub fn on_frame(&self, broker: &BrokerHandle, frame: &Frame) -> FrameOutcome {
        match frame.frame_type {
            FrameType::Heartbeat => {
                broker.touch(self.conn);
                FrameOutcome::Continue
            }
            FrameType::Goodbye => {
                log::debug!("session {}: peer said goodbye", self.conn);
                FrameOutcome::End
            }
            FrameType::Data => match ClientRequest::from_frame(frame) {
                Ok((req, req_id)) => {
                    if let ClientRequest::Hello { heartbeat_ms: hb, .. } = &req {
                        self.heartbeat_ms.store(*hb, Ordering::Relaxed);
                    }
                    let is_close = matches!(req, ClientRequest::Close);
                    broker.handle_with_reply(self.conn, &req, req_id);
                    if is_close {
                        FrameOutcome::End
                    } else {
                        FrameOutcome::Continue
                    }
                }
                Err(e) => {
                    // Protocol corruption: this connection cannot be
                    // trusted any further.
                    log::warn!("session {}: protocol error: {e}; dropping", self.conn);
                    FrameOutcome::End
                }
            },
        }
    }

    /// Tear the broker side down (requeues unacked messages, etc.).
    /// Idempotent — `disconnect` ignores unknown connections.
    pub fn finish(&self, broker: &BrokerHandle) {
        broker.disconnect(self.conn);
    }
}

/// Serve one connection until the peer closes, errors, or sends `Close`.
/// Blocks; callers spawn a thread (the threads-mode TCP server and the
/// inproc broker do).
pub fn serve_link(broker: BrokerHandle, link: Arc<dyn Link>) {
    let (tx, rx) = channel::<ServerMsg>();
    let session = SessionState::open(&broker, Outbound::Channel(tx.clone()));
    let conn = session.conn();

    let writer_link = Arc::clone(&link);
    let writer_hb = session.heartbeat_handle();
    let writer = std::thread::Builder::new()
        .name("kiwi-session-writer".into())
        .spawn(move || {
            loop {
                let hb = writer_hb.load(Ordering::Relaxed);
                let wait = if hb > 0 {
                    Duration::from_millis((hb / 2).max(1))
                } else {
                    Duration::from_millis(500)
                };
                match rx.recv_timeout(wait) {
                    Ok(msg) => {
                        // Coalesce whatever else is already queued into one
                        // write unit (bounded, so a flood cannot starve the
                        // heartbeat path indefinitely). Delivery frames
                        // reference the publisher's body buffers as
                        // sections — no per-frame payload assembly here.
                        let mut frames = vec![msg.to_frame()];
                        let mut disconnected = false;
                        while frames.len() < WRITE_COALESCE_MAX {
                            match rx.try_recv() {
                                Ok(m) => frames.push(m.to_frame()),
                                Err(TryRecvError::Empty) => break,
                                Err(TryRecvError::Disconnected) => {
                                    disconnected = true;
                                    break;
                                }
                            }
                        }
                        if writer_link.send_batch(&frames).is_err() || disconnected {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if hb > 0 && writer_link.send(&Frame::heartbeat()).is_err() {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        })
        .expect("spawn session writer");

    loop {
        match link.recv_timeout(Duration::from_millis(500)) {
            Ok(frame) => {
                if session.on_frame(&broker, &frame) == FrameOutcome::End {
                    break;
                }
            }
            Err(Error::Timeout(_)) => continue, // liveness is the monitor's job
            Err(e) => {
                log::debug!("session {conn}: link error: {e}");
                break;
            }
        }
    }
    session.finish(&broker);
    drop(tx);
    link.close();
    writer.join().ok();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::QueueOptions;
    use crate::transport::inproc_pair;
    use crate::wire::Value;

    /// Drive a session through a raw link, asserting the protocol works
    /// end-to-end without the client-side Connection sugar.
    #[test]
    fn raw_protocol_conversation() {
        let broker = BrokerHandle::new();
        let (client, server) = inproc_pair();
        let server: Arc<dyn Link> = Arc::new(server);
        let b2 = broker.clone();
        let session = std::thread::spawn(move || serve_link(b2, server));

        let send = |req: &ClientRequest, id: u64| {
            client.send(&req.to_frame(id)).unwrap();
        };
        let recv_data = || -> ServerMsg {
            loop {
                let f = client.recv_timeout(Duration::from_secs(2)).unwrap();
                if f.frame_type == FrameType::Data {
                    return ServerMsg::from_frame(&f).unwrap();
                }
            }
        };

        send(&ClientRequest::Hello { client_id: "t".into(), heartbeat_ms: 0 }, 1);
        assert!(matches!(recv_data(), ServerMsg::Ok { req_id: 1, .. }));

        send(
            &ClientRequest::QueueDeclare { queue: "q".into(), options: QueueOptions::default() },
            2,
        );
        assert!(matches!(recv_data(), ServerMsg::Ok { req_id: 2, .. }));

        send(
            &ClientRequest::Publish {
                exchange: "".into(),
                routing_key: "q".into(),
                body: crate::wire::Bytes::encode(&Value::str("m")),
                props: Default::default(),
                mandatory: true,
            },
            3,
        );
        assert!(matches!(recv_data(), ServerMsg::Ok { req_id: 3, .. }));

        send(
            &ClientRequest::Consume { queue: "q".into(), consumer_tag: "c".into(), prefetch: 0 },
            4,
        );
        // Ok for consume, then the delivery (order guaranteed: same channel).
        assert!(matches!(recv_data(), ServerMsg::Ok { req_id: 4, .. }));
        match recv_data() {
            ServerMsg::Deliver(d) => assert_eq!(d.body.decode().unwrap(), Value::str("m")),
            other => panic!("expected delivery, got {other:?}"),
        }

        send(&ClientRequest::Close, 5);
        assert!(matches!(recv_data(), ServerMsg::Ok { req_id: 5, .. }));
        session.join().unwrap();
    }

    #[test]
    fn error_reply_for_bad_request() {
        let broker = BrokerHandle::new();
        let (client, server) = inproc_pair();
        let server: Arc<dyn Link> = Arc::new(server);
        let b2 = broker.clone();
        let session = std::thread::spawn(move || serve_link(b2, server));

        client
            .send(
                &ClientRequest::Consume {
                    queue: "missing".into(),
                    consumer_tag: "c".into(),
                    prefetch: 0,
                }
                .to_frame(9),
            )
            .unwrap();
        let f = client.recv_timeout(Duration::from_secs(2)).unwrap();
        match ServerMsg::from_frame(&f).unwrap() {
            ServerMsg::Err { req_id, code, .. } => {
                assert_eq!(req_id, 9);
                assert_eq!(code, "broker");
            }
            other => panic!("expected err, got {other:?}"),
        }
        client.send(&Frame::goodbye("done")).unwrap();
        session.join().unwrap();
    }

    #[test]
    fn malformed_frame_drops_session_and_requeues() {
        let broker = BrokerHandle::new();
        let (client, server) = inproc_pair();
        let server: Arc<dyn Link> = Arc::new(server);
        let b2 = broker.clone();
        let session = std::thread::spawn(move || serve_link(b2, server));

        // A data frame whose payload is not a valid request.
        client.send(&Frame::data(&Value::str("garbage"))).unwrap();
        session.join().unwrap(); // session must terminate, not hang
        // Broker survives.
        assert_eq!(broker.metrics().gauge("broker.connections").get(), 0);
    }

    #[test]
    fn server_heartbeats_flow_after_hello() {
        let broker = BrokerHandle::new();
        let (client, server) = inproc_pair();
        let server: Arc<dyn Link> = Arc::new(server);
        let b2 = broker.clone();
        let session = std::thread::spawn(move || serve_link(b2, server));

        client
            .send(&ClientRequest::Hello { client_id: "hb".into(), heartbeat_ms: 20 }.to_frame(1))
            .unwrap();
        let mut saw_heartbeat = false;
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while std::time::Instant::now() < deadline {
            match client.recv_timeout(Duration::from_millis(100)) {
                Ok(f) if f.frame_type == FrameType::Heartbeat => {
                    saw_heartbeat = true;
                    break;
                }
                Ok(_) => continue,
                Err(_) => continue,
            }
        }
        assert!(saw_heartbeat, "server should emit heartbeats at hb/2");
        client.send(&Frame::goodbye("bye")).unwrap();
        session.join().unwrap();
    }
}
