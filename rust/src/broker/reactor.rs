//! Event-driven broker networking: one epoll reactor thread serving every
//! TCP connection.
//!
//! The thread-per-connection front-end (`broker::server` in `threads`
//! mode) costs two OS threads and two stacks per client, which caps a
//! broker at a few thousand connections. This module replaces it with a
//! single reactor thread:
//!
//! * a nonblocking listener accepted in bursts,
//! * readiness-driven reads decoded incrementally by [`FrameReader`]
//!   (large payload bodies land in their final buffer — no copy),
//! * a per-connection [`WriteQueue`] drained on writable edges, staging
//!   small frames into one buffer and shipping large delivery sections
//!   zero-copy by `Bytes` refcount — staged headers and sections go out
//!   together in one vectored `writev(2)` per batch,
//! * per-connection backpressure: when a connection's pending output
//!   exceeds `outbox_cap`, its [`ConnSink`] reports not-ready and the
//!   dispatcher stops *assigning* deliveries to that connection's
//!   consumers (messages stay in the ready queue for other consumers);
//!   when the socket drains below half the cap the reactor calls
//!   [`BrokerHandle::resume_deliveries`]. A slow consumer therefore
//!   stalls only itself, never the broker or its queue peers.
//!
//! Everything that ends a connection — Goodbye, `Close`, protocol
//! corruption, EOF, write error, heartbeat eviction, broker shutdown —
//! funnels through one teardown path on the reactor thread, so fd
//! deregistration and `disconnect` can never race.
//!
//! The epoll plumbing is hand-rolled over raw `syscall(2)` (no external
//! crates, per the crate's no-dependency rule) and gated to
//! linux/x86_64|aarch64; elsewhere [`supported`] returns false and the
//! server falls back to the threads front-end.

/// Default max epoll events handled per wakeup (`KIWI_EVENT_BATCH`).
pub const DEFAULT_EVENT_BATCH: usize = 256;
/// Default per-connection outbox soft cap in bytes (`KIWI_OUTBOX_CAP`).
pub const DEFAULT_OUTBOX_CAP: usize = 1 << 20;

/// Reactor tuning knobs (see `Config::net_options`).
#[derive(Clone, Copy, Debug)]
pub struct ReactorOptions {
    /// Max epoll events handled per wakeup.
    pub event_batch: usize,
    /// Per-connection outbox soft cap in bytes; crossing it pauses
    /// delivery assignment to that connection until it drains below half.
    pub outbox_cap: usize,
}

impl Default for ReactorOptions {
    fn default() -> Self {
        ReactorOptions { event_batch: DEFAULT_EVENT_BATCH, outbox_cap: DEFAULT_OUTBOX_CAP }
    }
}

/// Whether the epoll reactor can run on this target. When false the
/// server silently uses the threads front-end regardless of `KIWI_NET`.
pub fn supported() -> bool {
    cfg!(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use std::collections::{HashMap, VecDeque};
    use std::io::{self, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::os::unix::net::UnixStream;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    use super::ReactorOptions;
    use crate::broker::core::{BrokerHandle, ConnectionId, DeliverySink, Outbound};
    use crate::broker::protocol::ServerMsg;
    use crate::broker::session::{FrameOutcome, SessionState};
    use crate::error::{Error, Result};
    use crate::metrics::Counter;
    use crate::wire::{Bytes, Frame, FrameReader};

    /// Raw syscall shims for the handful of interfaces std does not
    /// expose. Numbers are per-arch; everything funnels through glibc's
    /// variadic `syscall(2)` so errno handling stays standard.
    mod sys {
        use std::io;
        use std::os::fd::{FromRawFd, OwnedFd, RawFd};
        use std::os::raw::{c_int, c_long};
        use std::time::Duration;

        extern "C" {
            fn syscall(num: c_long, ...) -> c_long;
        }

        #[cfg(target_arch = "x86_64")]
        mod nr {
            use std::os::raw::c_long;
            pub const WRITEV: c_long = 20;
            pub const EPOLL_CTL: c_long = 233;
            pub const PPOLL: c_long = 271;
            pub const EPOLL_PWAIT: c_long = 281;
            pub const EPOLL_CREATE1: c_long = 291;
            pub const PRLIMIT64: c_long = 302;
        }
        #[cfg(target_arch = "aarch64")]
        mod nr {
            use std::os::raw::c_long;
            pub const EPOLL_CREATE1: c_long = 20;
            pub const EPOLL_CTL: c_long = 21;
            pub const EPOLL_PWAIT: c_long = 22;
            pub const WRITEV: c_long = 66;
            pub const PPOLL: c_long = 73;
            pub const PRLIMIT64: c_long = 261;
        }

        pub const EPOLLIN: u32 = 0x1;
        pub const EPOLLOUT: u32 = 0x4;
        pub const EPOLLERR: u32 = 0x8;
        pub const EPOLLHUP: u32 = 0x10;
        pub const EPOLLRDHUP: u32 = 0x2000;
        const EPOLL_CLOEXEC: c_long = 0o2000000;
        pub const EPOLL_CTL_ADD: c_int = 1;
        pub const EPOLL_CTL_DEL: c_int = 2;
        pub const EPOLL_CTL_MOD: c_int = 3;

        /// Kernel epoll_event. Packed on x86_64 (the kernel ABI there),
        /// naturally aligned on aarch64. Fields are only ever read by
        /// value — never take a reference into a packed instance.
        #[repr(C)]
        #[cfg_attr(target_arch = "x86_64", repr(packed))]
        #[derive(Clone, Copy)]
        pub struct EpollEvent {
            pub events: u32,
            pub data: u64,
        }

        pub fn epoll_create1() -> io::Result<OwnedFd> {
            let r = unsafe { syscall(nr::EPOLL_CREATE1, EPOLL_CLOEXEC) };
            if r < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(unsafe { OwnedFd::from_raw_fd(r as RawFd) })
        }

        pub fn epoll_ctl(
            epfd: RawFd,
            op: c_int,
            fd: RawFd,
            event: Option<EpollEvent>,
        ) -> io::Result<()> {
            let mut ev = event.unwrap_or(EpollEvent { events: 0, data: 0 });
            let ptr: *mut EpollEvent = match event {
                Some(_) => &mut ev,
                None => std::ptr::null_mut(),
            };
            let r =
                unsafe { syscall(nr::EPOLL_CTL, epfd as c_long, op as c_long, fd as c_long, ptr) };
            if r < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Wait for events. Uses `epoll_pwait` (plain `epoll_wait` does
        /// not exist on aarch64) with a null sigmask. EINTR reports as
        /// zero events — the caller just loops.
        pub fn epoll_pwait(
            epfd: RawFd,
            events: &mut [EpollEvent],
            timeout: Duration,
        ) -> io::Result<usize> {
            let ms = timeout.as_millis().min(i32::MAX as u128) as c_long;
            let r = unsafe {
                syscall(
                    nr::EPOLL_PWAIT,
                    epfd as c_long,
                    events.as_mut_ptr(),
                    events.len() as c_long,
                    ms,
                    std::ptr::null::<u8>(),
                    8 as c_long,
                )
            };
            if r < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(0);
                }
                return Err(e);
            }
            Ok(r as usize)
        }

        /// Vectored write. `IoSlice` is guaranteed ABI-compatible with
        /// the kernel's `iovec`, so the slice passes straight through.
        pub fn writev(fd: RawFd, bufs: &[io::IoSlice<'_>]) -> io::Result<usize> {
            let r = unsafe {
                syscall(nr::WRITEV, fd as c_long, bufs.as_ptr(), bufs.len() as c_long)
            };
            if r < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(r as usize)
        }

        #[repr(C)]
        struct PollFd {
            fd: c_int,
            events: i16,
            revents: i16,
        }
        #[repr(C)]
        struct Timespec {
            tv_sec: i64,
            tv_nsec: i64,
        }
        const POLLIN: i16 = 0x1;

        /// Block until `fd` is readable or `timeout` elapses (via ppoll).
        pub fn poll_readable(fd: RawFd, timeout: Duration) -> io::Result<bool> {
            let mut pfd = PollFd { fd, events: POLLIN, revents: 0 };
            let ts = Timespec {
                tv_sec: timeout.as_secs().min(i64::MAX as u64) as i64,
                tv_nsec: i64::from(timeout.subsec_nanos()),
            };
            let r = unsafe {
                syscall(
                    nr::PPOLL,
                    &mut pfd as *mut PollFd,
                    1 as c_long,
                    &ts as *const Timespec,
                    std::ptr::null::<u8>(),
                    8 as c_long,
                )
            };
            if r < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(false);
                }
                return Err(e);
            }
            Ok(r > 0 && (pfd.revents & POLLIN) != 0)
        }

        #[repr(C)]
        struct RLimit64 {
            rlim_cur: u64,
            rlim_max: u64,
        }
        const RLIMIT_NOFILE: c_long = 7;

        /// Raise this process's soft RLIMIT_NOFILE toward `want` (capped
        /// at the hard limit). Returns the resulting soft limit.
        pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
            let mut cur = RLimit64 { rlim_cur: 0, rlim_max: 0 };
            let r = unsafe {
                syscall(
                    nr::PRLIMIT64,
                    0 as c_long,
                    RLIMIT_NOFILE,
                    std::ptr::null::<RLimit64>(),
                    &mut cur as *mut RLimit64,
                )
            };
            if r < 0 {
                return Err(io::Error::last_os_error());
            }
            let target = want.min(cur.rlim_max);
            if target <= cur.rlim_cur {
                return Ok(cur.rlim_cur);
            }
            let new = RLimit64 { rlim_cur: target, rlim_max: cur.rlim_max };
            let r = unsafe {
                syscall(
                    nr::PRLIMIT64,
                    0 as c_long,
                    RLIMIT_NOFILE,
                    &new as *const RLimit64,
                    std::ptr::null_mut::<RLimit64>(),
                )
            };
            if r < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(target)
        }
    }

    /// Raise the soft fd limit toward `want` — connection-storm tooling
    /// calls this before opening tens of thousands of sockets.
    pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
        sys::raise_nofile_limit(want)
    }

    /// Block until the listener is readable or `timeout` elapses. The
    /// threads-mode accept loop uses this instead of a fixed sleep so
    /// accept latency is bounded by the kernel, not a poll interval.
    pub fn listener_wait_readable(listener: &TcpListener, timeout: Duration) -> bool {
        sys::poll_readable(listener.as_raw_fd(), timeout).unwrap_or(false)
    }

    /// Thin level-triggered epoll wrapper keyed by u64 tokens.
    struct Poller {
        ep: std::os::fd::OwnedFd,
    }

    fn interest(writable: bool) -> u32 {
        sys::EPOLLIN | sys::EPOLLRDHUP | if writable { sys::EPOLLOUT } else { 0 }
    }

    impl Poller {
        fn new() -> io::Result<Poller> {
            Ok(Poller { ep: sys::epoll_create1()? })
        }

        fn add(&self, fd: std::os::fd::RawFd, token: u64, writable: bool) -> io::Result<()> {
            sys::epoll_ctl(
                self.ep.as_raw_fd(),
                sys::EPOLL_CTL_ADD,
                fd,
                Some(sys::EpollEvent { events: interest(writable), data: token }),
            )
        }

        fn modify(&self, fd: std::os::fd::RawFd, token: u64, writable: bool) -> io::Result<()> {
            sys::epoll_ctl(
                self.ep.as_raw_fd(),
                sys::EPOLL_CTL_MOD,
                fd,
                Some(sys::EpollEvent { events: interest(writable), data: token }),
            )
        }

        fn delete(&self, fd: std::os::fd::RawFd) {
            // Teardown path: the fd is closed right after this call and the
            // kernel drops the registration with it, so a failed DEL cannot
            // leak interest. It *can* flag a token/fd mix-up (EBADF/ENOENT
            // from a double-teardown), which is worth a log line.
            if let Err(e) = sys::epoll_ctl(self.ep.as_raw_fd(), sys::EPOLL_CTL_DEL, fd, None)
            {
                log::debug!("reactor: EPOLL_CTL_DEL({fd}) failed: {e}");
            }
        }

        fn wait(&self, events: &mut [sys::EpollEvent], timeout: Duration) -> io::Result<usize> {
            sys::epoll_pwait(self.ep.as_raw_fd(), events, timeout)
        }
    }

    /// Wakes the reactor from other threads (dispatcher shards, the
    /// heartbeat monitor, shutdown) and carries the set of connections
    /// with freshly-queued output ("dirty" tokens).
    ///
    /// The pipe is a nonblocking socketpair: a full pipe means a wakeup
    /// is already pending, so dropped writes are harmless. Dirty-token
    /// dedup lives in each sink's `enqueued` flag; the flag is cleared by
    /// the reactor *before* it drains the sink's queue, so a concurrent
    /// push always lands either in the drained batch or back on the
    /// dirty list — never lost.
    pub(super) struct Waker {
        pipe: UnixStream,
        dirty: Mutex<Vec<u64>>,
    }

    impl Waker {
        fn notify(&self, token: u64, enqueued: &AtomicBool) {
            if !enqueued.swap(true, Ordering::AcqRel) {
                self.dirty.lock().unwrap().push(token);
                self.ring();
            }
        }

        pub(super) fn ring(&self) {
            // Per the struct doc, WouldBlock means a wakeup is already
            // pending and BrokenPipe means the reactor is tearing down —
            // both safe to drop. Any other error would mean wakeups are
            // silently lost (stalled deliveries), so surface it.
            if let Err(e) = (&self.pipe).write(&[1u8]) {
                if e.kind() != io::ErrorKind::WouldBlock
                    && e.kind() != io::ErrorKind::BrokenPipe
                {
                    log::warn!("reactor: waker ring failed: {e}");
                }
            }
        }

        fn drain_dirty(&self) -> Vec<u64> {
            std::mem::take(&mut *self.dirty.lock().unwrap())
        }
    }

    fn waker_pair() -> io::Result<(Arc<Waker>, UnixStream)> {
        let (w, r) = UnixStream::pair()?;
        w.set_nonblocking(true)?;
        r.set_nonblocking(true)?;
        Ok((Arc::new(Waker { pipe: w, dirty: Mutex::new(Vec::new()) }), r))
    }

    struct SinkInner {
        queue: VecDeque<ServerMsg>,
        /// Estimated encoded bytes of `queue` (payload + small overhead).
        est_bytes: usize,
        closed: bool,
    }

    /// The reactor's [`DeliverySink`]: an unbounded-in-count,
    /// byte-estimated outbox. Capacity is enforced upstream — `ready()`
    /// turning false stops delivery *assignment*, so control messages
    /// (replies, cancels) always fit and are never dropped.
    ///
    /// Leaf lock: `push`/`ready`/`close` are called under shard locks and
    /// must not call back into the broker (see core's lock order).
    pub(super) struct ConnSink {
        token: u64,
        cap: usize,
        waker: Arc<Waker>,
        inner: Mutex<SinkInner>,
        /// Token-on-dirty-list dedup flag (see [`Waker`]).
        enqueued: AtomicBool,
        /// True while delivery assignment to this connection is paused.
        paused: AtomicBool,
        closed: AtomicBool,
        pauses: Arc<Counter>,
    }

    /// Rough wire size of one outbound message: exact for the dominant
    /// payload bytes (shared buffers, not copied here), a small constant
    /// for envelope overhead. Only used for backpressure accounting.
    fn estimate_msg_bytes(msg: &ServerMsg) -> usize {
        match msg {
            ServerMsg::Deliver(d) => 96 + d.body.len() + d.props.bytes().len(),
            ServerMsg::DeliverBatch(ds) => {
                32 + ds.iter().map(|d| 96 + d.body.len() + d.props.bytes().len()).sum::<usize>()
            }
            _ => 128,
        }
    }

    impl ConnSink {
        fn new(token: u64, cap: usize, waker: Arc<Waker>, pauses: Arc<Counter>) -> Arc<ConnSink> {
            Arc::new(ConnSink {
                token,
                cap: cap.max(1),
                waker,
                inner: Mutex::new(SinkInner {
                    queue: VecDeque::new(),
                    est_bytes: 0,
                    closed: false,
                }),
                enqueued: AtomicBool::new(false),
                paused: AtomicBool::new(false),
                closed: AtomicBool::new(false),
                pauses,
            })
        }

        /// Take everything queued, returning (messages, closed). Resets
        /// the byte estimate; the reactor re-books those bytes in the
        /// connection's [`WriteQueue`].
        fn drain(&self) -> (Vec<ServerMsg>, bool) {
            let mut g = self.inner.lock().unwrap();
            g.est_bytes = 0;
            (g.queue.drain(..).collect(), g.closed)
        }

        fn pending_est(&self) -> usize {
            self.inner.lock().unwrap().est_bytes
        }

        fn set_paused(&self, v: bool) {
            if v {
                if !self.paused.swap(true, Ordering::AcqRel) {
                    self.pauses.inc();
                }
            } else {
                self.paused.store(false, Ordering::Release);
            }
        }

        fn is_paused(&self) -> bool {
            self.paused.load(Ordering::Acquire)
        }

        /// Mark closed without waking the reactor — used by the reactor's
        /// own teardown, where a wakeup for a just-removed token would be
        /// noise.
        fn clear_enqueued(&self) {
            self.enqueued.store(false, Ordering::Release);
        }

        fn close_silent(&self) {
            self.inner.lock().unwrap().closed = true;
            self.closed.store(true, Ordering::Release);
        }
    }

    impl DeliverySink for ConnSink {
        fn push(&self, msg: ServerMsg) -> bool {
            let est = estimate_msg_bytes(&msg);
            let should_pause = {
                let mut g = self.inner.lock().unwrap();
                if g.closed {
                    return false;
                }
                g.est_bytes += est;
                g.queue.push_back(msg);
                g.est_bytes >= self.cap
            };
            if should_pause {
                self.set_paused(true);
            }
            self.waker.notify(self.token, &self.enqueued);
            true
        }

        fn ready(&self) -> bool {
            !self.paused.load(Ordering::Acquire) && !self.closed.load(Ordering::Acquire)
        }

        fn close(&self) {
            {
                let mut g = self.inner.lock().unwrap();
                if g.closed {
                    return;
                }
                g.closed = true;
            }
            self.closed.store(true, Ordering::Release);
            // Wake the reactor so it flushes what it can and drops the fd.
            self.waker.notify(self.token, &self.enqueued);
        }
    }

    /// Small frames staged into one contiguous buffer before this many
    /// bytes force a chunk cut.
    const STAGE_FLUSH_BYTES: usize = 32 * 1024;
    /// Frame sections at or above this size ship as their own chunk —
    /// a refcount clone of the publisher's buffer, no copy.
    const SECTION_ZERO_COPY_MIN: usize = 1024;

    /// Upper bound on iovec entries per `writev` batch. Linux caps at
    /// IOV_MAX (1024); 64 covers a full staged-plus-sections burst while
    /// keeping the per-call slice table small.
    const WRITEV_BATCH: usize = 64;

    /// Per-connection pending output: a chunk list drained with vectored
    /// nonblocking `writev(2)`. Small frames coalesce into staged buffers;
    /// large delivery bodies are appended as shared [`Bytes`] views of the
    /// publisher's original encode, and one syscall ships the staged
    /// header buffer plus every zero-copy section together.
    pub(super) struct WriteQueue {
        chunks: VecDeque<Bytes>,
        /// Bytes of `chunks.front()` already written.
        head_pos: usize,
        staged: Vec<u8>,
        /// Total unwritten bytes (staged + chunked).
        queued: usize,
    }

    impl WriteQueue {
        fn new() -> WriteQueue {
            WriteQueue { chunks: VecDeque::new(), head_pos: 0, staged: Vec::new(), queued: 0 }
        }

        fn queued_bytes(&self) -> usize {
            self.queued
        }

        fn is_empty(&self) -> bool {
            self.queued == 0
        }

        fn push_frame(&mut self, frame: &Frame) {
            let len = frame.wire_len();
            let mut header = [0u8; 5];
            header[..4].copy_from_slice(&(len as u32).to_le_bytes());
            header[4] = frame.frame_type as u8;
            self.staged.extend_from_slice(&header);
            self.staged.extend_from_slice(&frame.payload);
            for s in &frame.sections {
                if s.len() >= SECTION_ZERO_COPY_MIN {
                    self.flush_staged();
                    self.chunks.push_back(s.clone());
                } else {
                    self.staged.extend_from_slice(s);
                }
            }
            if self.staged.len() >= STAGE_FLUSH_BYTES {
                self.flush_staged();
            }
            self.queued += 5 + len;
        }

        fn flush_staged(&mut self) {
            if !self.staged.is_empty() {
                self.chunks.push_back(Bytes::from_vec(std::mem::take(&mut self.staged)));
            }
        }

        /// Advance the queue past `n` freshly written bytes, popping
        /// fully-written chunks and tracking the partial head offset.
        fn consume(&mut self, mut n: usize) {
            self.queued -= n;
            while n > 0 {
                let front_len = self.chunks.front().expect("consumed past queue end").len();
                let remaining = front_len - self.head_pos;
                if n >= remaining {
                    n -= remaining;
                    self.chunks.pop_front();
                    self.head_pos = 0;
                } else {
                    self.head_pos += n;
                    n = 0;
                }
            }
        }

        /// Write until drained or the sink would block. Returns true
        /// when everything queued has been written. Generic fallback for
        /// tests and non-fd sinks; the reactor's hot path is
        /// [`WriteQueue::write_to_fd`].
        fn write_to<W: Write>(&mut self, mut w: W) -> io::Result<bool> {
            self.flush_staged();
            loop {
                let n = {
                    let Some(front) = self.chunks.front() else { return Ok(true) };
                    match w.write(&front[self.head_pos..]) {
                        Ok(0) => {
                            return Err(io::Error::new(
                                io::ErrorKind::WriteZero,
                                "connection write returned zero",
                            ))
                        }
                        Ok(n) => n,
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                        Err(e) => return Err(e),
                    }
                };
                self.consume(n);
            }
        }

        /// Drain into `fd` with vectored `writev`: up to [`WRITEV_BATCH`]
        /// chunks — the staged header buffer and the refcounted zero-copy
        /// sections behind it — go out in one syscall instead of one
        /// `write(2)` each. Same contract as [`WriteQueue::write_to`]:
        /// returns true when everything queued has been written, false on
        /// would-block.
        fn write_to_fd(&mut self, fd: std::os::fd::RawFd) -> io::Result<bool> {
            self.flush_staged();
            loop {
                if self.chunks.is_empty() {
                    return Ok(true);
                }
                let n = {
                    let mut iov: Vec<io::IoSlice<'_>> =
                        Vec::with_capacity(self.chunks.len().min(WRITEV_BATCH));
                    for (i, c) in self.chunks.iter().take(WRITEV_BATCH).enumerate() {
                        let s = if i == 0 { &c[self.head_pos..] } else { &c[..] };
                        iov.push(io::IoSlice::new(s));
                    }
                    match sys::writev(fd, &iov) {
                        Ok(0) => {
                            return Err(io::Error::new(
                                io::ErrorKind::WriteZero,
                                "connection write returned zero",
                            ))
                        }
                        Ok(n) => n,
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                        Err(e) => return Err(e),
                    }
                };
                self.consume(n);
            }
        }
    }

    const LISTENER_TOKEN: u64 = 0;
    const WAKE_TOKEN: u64 = 1;
    const FIRST_CONN_TOKEN: u64 = 2;
    /// Shared read buffer for small frames (large payloads bypass it via
    /// `FrameReader::direct_buf`).
    const SCRATCH_BYTES: usize = 64 * 1024;
    /// Max read() calls per connection per readiness event — bounds how
    /// long one firehose connection can hog the loop.
    const READ_BURST: usize = 16;
    /// Max accepts per listener readiness event.
    const ACCEPT_BURST: usize = 256;
    /// Upper bound on one epoll wait (keeps the stop flag responsive).
    const MAX_POLL: Duration = Duration::from_millis(250);

    struct Conn {
        stream: TcpStream,
        session: SessionState,
        sink: Arc<ConnSink>,
        reader: FrameReader,
        out: WriteQueue,
        /// Whether EPOLLOUT is currently part of this fd's interest set.
        want_write: bool,
        /// Next server->client heartbeat due time (None until Hello
        /// negotiates an interval).
        next_hb: Option<Instant>,
        /// End-of-session seen; flush `out`, then tear down.
        closing: bool,
        peer: String,
    }

    struct Reactor {
        broker: BrokerHandle,
        poller: Poller,
        listener: TcpListener,
        wake_rx: UnixStream,
        waker: Arc<Waker>,
        stop: Arc<AtomicBool>,
        conns: HashMap<u64, Conn>,
        next_token: u64,
        opts: ReactorOptions,
        scratch: Vec<u8>,
        next_hb_scan: Instant,
        ctr_accepts: Arc<Counter>,
        ctr_pauses: Arc<Counter>,
    }

    impl Reactor {
        fn run(&mut self) {
            let nevents = self.opts.event_batch.clamp(8, 4096);
            let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; nevents];
            while !self.stop.load(Ordering::Acquire) {
                let now = Instant::now();
                let timeout = if now >= self.next_hb_scan {
                    Duration::from_millis(1)
                } else {
                    (self.next_hb_scan - now).min(MAX_POLL)
                };
                let n = match self.poller.wait(&mut events, timeout) {
                    Ok(n) => n,
                    Err(e) => {
                        log::error!("reactor: epoll wait failed: {e}; shutting down front-end");
                        break;
                    }
                };
                for ev in events.iter().take(n) {
                    // Copy fields out by value (the struct is packed on
                    // x86_64; references into it are not allowed).
                    let token = ev.data;
                    let bits = ev.events;
                    match token {
                        LISTENER_TOKEN => self.accept_ready(),
                        WAKE_TOKEN => self.drain_wake_pipe(),
                        _ => {
                            if bits & sys::EPOLLOUT != 0 {
                                self.write_conn(token);
                            }
                            if bits
                                & (sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP)
                                != 0
                            {
                                self.read_ready(token);
                            }
                        }
                    }
                }
                for token in self.waker.drain_dirty() {
                    self.flush_outbound(token);
                }
                self.tick_heartbeats();
            }
            self.shutdown_all();
        }

        fn accept_ready(&mut self) {
            for _ in 0..ACCEPT_BURST {
                match self.listener.accept() {
                    Ok((stream, addr)) => self.install_conn(stream, addr.to_string()),
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        // EMFILE and friends: back off briefly so a fd
                        // exhaustion storm cannot hot-spin the loop.
                        log::warn!("reactor: accept failed: {e}");
                        std::thread::sleep(Duration::from_millis(5));
                        return;
                    }
                }
            }
        }

        fn install_conn(&mut self, stream: TcpStream, peer: String) {
            if let Err(e) = stream.set_nonblocking(true) {
                log::warn!("reactor: {peer}: set_nonblocking failed: {e}");
                return;
            }
            // Delivery batches are already coalesced into single writes;
            // Nagle on top of that only adds latency. Failure is cosmetic —
            // the connection works, just with worse latency — so log it
            // instead of rejecting the accept.
            if let Err(e) = stream.set_nodelay(true) {
                log::debug!("reactor: {peer}: set_nodelay failed: {e}");
            }
            let token = self.next_token;
            self.next_token += 1;
            // Register with epoll *before* creating broker state so a
            // registration failure leaves nothing to unwind.
            if let Err(e) = self.poller.add(stream.as_raw_fd(), token, false) {
                log::warn!("reactor: {peer}: epoll register failed: {e}");
                return;
            }
            let sink = ConnSink::new(
                token,
                self.opts.outbox_cap,
                Arc::clone(&self.waker),
                Arc::clone(&self.ctr_pauses),
            );
            let dyn_sink: Arc<dyn DeliverySink> = sink.clone();
            let session = SessionState::open(&self.broker, Outbound::Sink(dyn_sink));
            self.ctr_accepts.inc();
            self.conns.insert(
                token,
                Conn {
                    stream,
                    session,
                    sink,
                    reader: FrameReader::new(),
                    out: WriteQueue::new(),
                    want_write: false,
                    next_hb: None,
                    closing: false,
                    peer,
                },
            );
        }

        fn read_ready(&mut self, token: u64) {
            let broker = self.broker.clone();
            let mut dead = false;
            let mut end = false;
            {
                let Some(conn) = self.conns.get_mut(&token) else { return };
                if conn.closing {
                    // Draining our side; ignore further input.
                    return;
                }
                'burst: for _ in 0..READ_BURST {
                    // Large payloads read straight into the frame's final
                    // buffer; everything else goes through scratch.
                    let (r, used_direct, want) = match conn.reader.direct_buf() {
                        Some(dst) => {
                            let want = dst.len();
                            ((&conn.stream).read(dst), true, want)
                        }
                        None => {
                            ((&conn.stream).read(&mut self.scratch[..]), false, self.scratch.len())
                        }
                    };
                    match r {
                        Ok(0) => {
                            dead = true;
                            break 'burst;
                        }
                        Ok(n) => {
                            if used_direct {
                                conn.reader.advance_direct(n);
                            } else if let Err(e) = conn.reader.feed(&self.scratch[..n]) {
                                log::warn!("reactor: {}: protocol error: {e}", conn.peer);
                                dead = true;
                                break 'burst;
                            }
                            while let Some(frame) = conn.reader.next_frame() {
                                if conn.session.on_frame(&broker, &frame) == FrameOutcome::End {
                                    end = true;
                                    break;
                                }
                            }
                            if end || n < want {
                                break 'burst;
                            }
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break 'burst,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                        Err(e) => {
                            log::debug!("reactor: {}: read error: {e}", conn.peer);
                            dead = true;
                            break 'burst;
                        }
                    }
                }
            }
            if dead {
                self.teardown(token);
            } else if end {
                self.begin_close(token);
            }
        }

        /// Orderly end (Goodbye / Close / corruption): flush the sink
        /// into the write queue, stop reading, tear down once drained —
        /// so the Close reply reaches the wire before the fd drops.
        fn begin_close(&mut self, token: u64) {
            self.encode_pending(token);
            match self.conns.get_mut(&token) {
                Some(conn) => conn.closing = true,
                None => return,
            }
            self.write_conn(token);
        }

        /// Move everything queued in the connection's sink into its write
        /// queue. Returns true when the sink was closed by the broker
        /// side. Clears the dirty-dedup flag *before* draining so a
        /// concurrent push cannot be lost.
        fn encode_pending(&mut self, token: u64) -> bool {
            let Some(conn) = self.conns.get_mut(&token) else { return false };
            conn.sink.clear_enqueued();
            let (msgs, closed) = conn.sink.drain();
            for m in &msgs {
                conn.out.push_frame(&m.to_frame());
            }
            closed
        }

        /// Dirty-token handler: encode freshly-queued messages and write.
        fn flush_outbound(&mut self, token: u64) {
            if !self.conns.contains_key(&token) {
                // Teardown raced the wakeup; nothing left to flush.
                return;
            }
            let closed = self.encode_pending(token);
            let already_closing = self.conns.get(&token).is_some_and(|c| c.closing);
            if closed && !already_closing {
                // Broker-initiated eviction (heartbeat death, duplicate
                // client, shutdown): one best-effort flush, then drop.
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.closing = true;
                }
                self.write_conn(token);
                if self.conns.contains_key(&token) {
                    self.teardown(token);
                }
            } else {
                self.write_conn(token);
            }
        }

        /// Drain the write queue into the socket; manage EPOLLOUT
        /// interest, closing-drain teardown and backpressure transitions.
        fn write_conn(&mut self, token: u64) {
            enum After {
                None,
                Teardown,
                Resume(ConnectionId),
            }
            let mut after = After::None;
            {
                let Some(conn) = self.conns.get_mut(&token) else { return };
                match conn.out.write_to_fd(conn.stream.as_raw_fd()) {
                    Ok(drained) => {
                        let want_write = !drained;
                        if want_write != conn.want_write {
                            // Edge-manage EPOLLOUT: only subscribed while
                            // output is actually pending, so an idle
                            // writable socket never spins the loop.
                            match self.poller.modify(conn.stream.as_raw_fd(), token, want_write) {
                                Ok(()) => conn.want_write = want_write,
                                Err(e) => {
                                    log::warn!("reactor: {}: epoll modify failed: {e}", conn.peer);
                                    after = After::Teardown;
                                }
                            }
                        }
                        if matches!(after, After::None) {
                            if drained && conn.closing {
                                after = After::Teardown;
                            } else if !conn.closing {
                                let backlog = conn.out.queued_bytes() + conn.sink.pending_est();
                                if backlog >= self.opts.outbox_cap {
                                    conn.sink.set_paused(true);
                                } else if conn.sink.is_paused()
                                    && backlog <= self.opts.outbox_cap / 2
                                {
                                    // Low-water resume: re-run dispatch for
                                    // this connection's queues now that the
                                    // socket caught up.
                                    conn.sink.set_paused(false);
                                    after = After::Resume(conn.session.conn());
                                }
                            }
                        }
                    }
                    Err(e) => {
                        log::debug!("reactor: {}: write error: {e}", conn.peer);
                        after = After::Teardown;
                    }
                }
            }
            match after {
                After::None => {}
                After::Teardown => self.teardown(token),
                After::Resume(conn_id) => self.broker.resume_deliveries(conn_id),
            }
        }

        /// The single exit path: deregister, close the sink, disconnect
        /// the broker side (requeues unacked), drop the fd.
        fn teardown(&mut self, token: u64) {
            let Some(conn) = self.conns.remove(&token) else { return };
            self.poller.delete(conn.stream.as_raw_fd());
            conn.sink.close_silent();
            conn.session.finish(&self.broker);
            // `conn.stream` drops here — the fd closes after leaving the
            // epoll set, never before.
        }

        /// Emit server->client heartbeats at half each connection's
        /// negotiated interval. Unconditional emission is always safe:
        /// clients only *require* traffic, they never penalise extra.
        fn tick_heartbeats(&mut self) {
            let now = Instant::now();
            if now < self.next_hb_scan {
                return;
            }
            let mut due: Vec<u64> = Vec::new();
            let mut min_half: Option<u64> = None;
            for (token, conn) in self.conns.iter_mut() {
                if conn.closing {
                    continue;
                }
                let hb = conn.session.heartbeat_ms();
                if hb == 0 {
                    conn.next_hb = None;
                    continue;
                }
                let half = (hb / 2).max(1);
                min_half = Some(min_half.map_or(half, |m| m.min(half)));
                match conn.next_hb {
                    None => conn.next_hb = Some(now + Duration::from_millis(half)),
                    Some(t) if now >= t => {
                        conn.out.push_frame(&Frame::heartbeat());
                        conn.next_hb = Some(now + Duration::from_millis(half));
                        due.push(*token);
                    }
                    Some(_) => {}
                }
            }
            for token in due {
                self.write_conn(token);
            }
            // Scan again at a quarter of the tightest interval (bounded)
            // so a due heartbeat is never more than half a period late.
            self.next_hb_scan = now
                + min_half.map_or(MAX_POLL, |h| {
                    Duration::from_millis(h / 2)
                        .clamp(Duration::from_millis(5), Duration::from_secs(1))
                });
        }

        fn drain_wake_pipe(&mut self) {
            let mut buf = [0u8; 256];
            loop {
                match (&self.wake_rx).read(&mut buf) {
                    Ok(0) => return,
                    Ok(_) => continue,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return,
                }
            }
        }

        fn shutdown_all(&mut self) {
            let tokens: Vec<u64> = self.conns.keys().copied().collect();
            for token in tokens {
                self.teardown(token);
            }
        }
    }

    /// Handle to a running reactor. The server sets the shared stop flag,
    /// calls [`ReactorHandle::wake`], then [`ReactorHandle::join`].
    pub struct ReactorHandle {
        waker: Arc<Waker>,
        thread: Option<std::thread::JoinHandle<()>>,
    }

    impl ReactorHandle {
        pub(crate) fn wake(&self) {
            self.waker.ring();
        }

        pub(crate) fn join(&mut self) {
            if let Some(t) = self.thread.take() {
                t.join().ok();
            }
        }
    }

    /// Start the reactor thread serving `listener` for `broker`.
    pub fn spawn(
        broker: BrokerHandle,
        listener: TcpListener,
        opts: ReactorOptions,
        stop: Arc<AtomicBool>,
    ) -> Result<ReactorHandle> {
        listener.set_nonblocking(true).map_err(Error::Io)?;
        let poller = Poller::new().map_err(Error::Io)?;
        let (waker, wake_rx) = waker_pair().map_err(Error::Io)?;
        poller.add(listener.as_raw_fd(), LISTENER_TOKEN, false).map_err(Error::Io)?;
        poller.add(wake_rx.as_raw_fd(), WAKE_TOKEN, false).map_err(Error::Io)?;
        let ctr_accepts = broker.metrics().counter("broker.reactor.accepts");
        let ctr_pauses = broker.metrics().counter("broker.reactor.backpressure_pauses_total");
        let mut reactor = Reactor {
            broker,
            poller,
            listener,
            wake_rx,
            waker: Arc::clone(&waker),
            stop,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            opts,
            scratch: vec![0u8; SCRATCH_BYTES],
            next_hb_scan: Instant::now(),
            ctr_accepts,
            ctr_pauses,
        };
        let thread = std::thread::Builder::new()
            .name("kiwi-broker-reactor".into())
            .spawn(move || reactor.run())
            .map_err(Error::Io)?;
        Ok(ReactorHandle { waker, thread: Some(thread) })
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::broker::protocol::{ClientRequest, QueueOptions};
        use crate::wire::{read_frame, write_frame, FrameType, Value};

        #[test]
        fn write_queue_ships_large_sections_zero_copy() {
            let body = Bytes::from_vec(vec![7u8; 8 * 1024]);
            let frame = Frame::data_with_sections(
                &Value::map([("len", Value::from(body.len()))]),
                vec![body.clone()],
            );
            let mut wq = WriteQueue::new();
            wq.push_frame(&frame);
            assert_eq!(wq.queued_bytes(), 5 + frame.wire_len());
            // The big section must be a refcount clone, not a copy.
            assert!(
                wq.chunks.iter().any(|c| Bytes::same_buffer(c, &body)),
                "large section should share the publisher's buffer"
            );
            let mut wire = Vec::new();
            assert!(wq.write_to(&mut wire).unwrap());
            assert!(wq.is_empty());
            let mut expect = Vec::new();
            write_frame(&mut expect, &frame).unwrap();
            assert_eq!(wire, expect);
        }

        #[test]
        fn write_queue_coalesces_small_frames_and_tracks_bytes() {
            let mut wq = WriteQueue::new();
            let frames: Vec<Frame> =
                (0..10).map(|i| Frame::data(&Value::str(format!("m{i}")))).collect();
            let mut expect = Vec::new();
            for f in &frames {
                wq.push_frame(f);
                write_frame(&mut expect, f).unwrap();
            }
            assert_eq!(wq.queued_bytes(), expect.len());
            // All ten frames staged into one contiguous chunk.
            wq.flush_staged();
            assert_eq!(wq.chunks.len(), 1);
            let mut wire = Vec::new();
            assert!(wq.write_to(&mut wire).unwrap());
            assert_eq!(wire, expect);
            assert_eq!(wq.queued_bytes(), 0);
        }

        /// The vectored fast path against a real socket: a mix of staged
        /// small frames and zero-copy sections, drained through
        /// `write_to_fd` across several would-block cycles, must land on
        /// the wire byte-identical to the `write_frame` reference.
        #[test]
        fn write_queue_drains_vectored_through_a_socket() {
            let (a, b) = UnixStream::pair().unwrap();
            a.set_nonblocking(true).unwrap();
            b.set_nonblocking(true).unwrap();
            let body = Bytes::from_vec(vec![9u8; 256 * 1024]);
            let big = Frame::data_with_sections(
                &Value::map([("len", Value::from(body.len()))]),
                vec![body],
            );
            let mut wq = WriteQueue::new();
            let mut expect = Vec::new();
            for i in 0..4 {
                let small = Frame::data(&Value::str(format!("s{i}")));
                wq.push_frame(&small);
                write_frame(&mut expect, &small).unwrap();
                wq.push_frame(&big);
                write_frame(&mut expect, &big).unwrap();
            }
            // ~1 MiB queued vs a ~200 KiB socket buffer: forces partial
            // writes, head-offset resumes, and WouldBlock returns.
            let mut got = Vec::new();
            let mut buf = vec![0u8; 64 * 1024];
            loop {
                let drained = wq.write_to_fd(a.as_raw_fd()).unwrap();
                loop {
                    match (&b).read(&mut buf) {
                        Ok(0) => break,
                        Ok(n) => got.extend_from_slice(&buf[..n]),
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(e) => panic!("read: {e}"),
                    }
                }
                if drained {
                    break;
                }
            }
            assert!(wq.is_empty());
            assert_eq!(got, expect);
        }

        #[test]
        fn conn_sink_pauses_dedups_and_closes() {
            let (waker, _rx) = waker_pair().unwrap();
            let pauses = crate::metrics::Registry::new().counter("t.pauses");
            let sink = ConnSink::new(5, 256, Arc::clone(&waker), Arc::clone(&pauses));
            assert!(sink.ready());
            let msg = || ServerMsg::Ok { req_id: 1, reply: Value::Null };
            assert!(sink.push(msg()));
            assert!(sink.push(msg()));
            // Two pushes, one dirty entry (the dedup flag).
            assert_eq!(waker.drain_dirty(), vec![5]);
            assert!(waker.drain_dirty().is_empty());
            // 128 bytes estimated per control message: the third crosses
            // the 256-byte cap and pauses the sink.
            assert!(sink.push(msg()));
            assert!(!sink.ready());
            assert_eq!(pauses.get(), 1);
            let (msgs, closed) = sink.drain();
            assert_eq!(msgs.len(), 3);
            assert!(!closed);
            sink.set_paused(false);
            assert!(sink.ready());
            sink.close();
            assert!(!sink.ready());
            assert!(!sink.push(msg()), "push after close must fail");
        }

        #[test]
        fn poller_reports_readable() {
            let poller = Poller::new().unwrap();
            let (a, b) = UnixStream::pair().unwrap();
            a.set_nonblocking(true).unwrap();
            poller.add(a.as_raw_fd(), 42, false).unwrap();
            let mut events = [sys::EpollEvent { events: 0, data: 0 }; 4];
            // Nothing readable yet.
            assert_eq!(poller.wait(&mut events, Duration::from_millis(10)).unwrap(), 0);
            (&b).write_all(&[1u8]).unwrap();
            let n = poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
            assert_eq!(n, 1);
            let token = events[0].data;
            let bits = events[0].events;
            assert_eq!(token, 42);
            assert_ne!(bits & sys::EPOLLIN, 0);
            poller.delete(a.as_raw_fd());
        }

        #[test]
        fn raise_nofile_limit_is_monotone() {
            let got = raise_nofile_limit(1024).unwrap();
            assert!(got >= 1024 || got > 0, "soft limit should be positive");
        }

        /// Full protocol conversation against a live reactor over real
        /// TCP: hello, declare, publish, consume, delivery, close — then
        /// a clean shutdown with no connections left behind.
        #[test]
        fn reactor_serves_a_raw_tcp_conversation() {
            let broker = BrokerHandle::new();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let stop = Arc::new(AtomicBool::new(false));
            let mut handle =
                spawn(broker.clone(), listener, ReactorOptions::default(), Arc::clone(&stop))
                    .unwrap();

            let client = TcpStream::connect(addr).unwrap();
            client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

            fn send(client: &TcpStream, req: &ClientRequest, id: u64) {
                let mut w = client;
                write_frame(&mut w, &req.to_frame(id)).unwrap();
            }
            fn recv_data(client: &TcpStream) -> ServerMsg {
                let mut r = client;
                loop {
                    let f = read_frame(&mut r).unwrap();
                    if f.frame_type == FrameType::Data {
                        return ServerMsg::from_frame(&f).unwrap();
                    }
                }
            }

            send(&client, &ClientRequest::Hello { client_id: "rx".into(), heartbeat_ms: 0 }, 1);
            assert!(matches!(recv_data(&client), ServerMsg::Ok { req_id: 1, .. }));
            send(
                &client,
                &ClientRequest::QueueDeclare {
                    queue: "q".into(),
                    options: QueueOptions::default(),
                },
                2,
            );
            assert!(matches!(recv_data(&client), ServerMsg::Ok { req_id: 2, .. }));
            send(
                &client,
                &ClientRequest::Publish {
                    exchange: "".into(),
                    routing_key: "q".into(),
                    body: Bytes::encode(&Value::str("payload")),
                    props: Default::default(),
                    mandatory: true,
                },
                3,
            );
            assert!(matches!(recv_data(&client), ServerMsg::Ok { req_id: 3, .. }));
            send(
                &client,
                &ClientRequest::Consume {
                    queue: "q".into(),
                    consumer_tag: "c".into(),
                    prefetch: 0,
                },
                4,
            );
            assert!(matches!(recv_data(&client), ServerMsg::Ok { req_id: 4, .. }));
            match recv_data(&client) {
                ServerMsg::Deliver(d) => {
                    assert_eq!(d.body.decode().unwrap(), Value::str("payload"))
                }
                other => panic!("expected delivery, got {other:?}"),
            }
            send(&client, &ClientRequest::Close, 5);
            assert!(matches!(recv_data(&client), ServerMsg::Ok { req_id: 5, .. }));

            // The reactor tears the connection down after Close.
            let deadline = Instant::now() + Duration::from_secs(5);
            while broker.metrics().gauge("broker.connections").get() != 0 {
                assert!(Instant::now() < deadline, "connection should be torn down");
                std::thread::sleep(Duration::from_millis(5));
            }
            stop.store(true, Ordering::Release);
            handle.wake();
            handle.join();
        }
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub use imp::{listener_wait_readable, raise_nofile_limit, spawn, ReactorHandle};

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod fallback {
    use std::io;
    use std::net::TcpListener;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::time::Duration;

    use super::ReactorOptions;
    use crate::broker::core::BrokerHandle;
    use crate::error::{Error, Result};

    /// Stub handle for unsupported targets (never constructed).
    pub struct ReactorHandle;

    impl ReactorHandle {
        pub(crate) fn wake(&self) {}
        pub(crate) fn join(&mut self) {}
    }

    pub fn spawn(
        _broker: BrokerHandle,
        _listener: TcpListener,
        _opts: ReactorOptions,
        _stop: Arc<AtomicBool>,
    ) -> Result<ReactorHandle> {
        Err(Error::Config(
            "epoll reactor requires linux on x86_64/aarch64; use KIWI_NET=threads".into(),
        ))
    }

    pub fn raise_nofile_limit(_want: u64) -> io::Result<u64> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "prlimit64 unavailable on this platform"))
    }

    pub fn listener_wait_readable(_listener: &TcpListener, timeout: Duration) -> bool {
        std::thread::sleep(timeout.min(Duration::from_millis(10)));
        false
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub use fallback::{listener_wait_readable, raise_nofile_limit, spawn, ReactorHandle};
