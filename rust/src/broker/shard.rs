//! Queue shards: the broker's data plane is split into N independent
//! shards, each a `Mutex` over a disjoint subset of queues (hash of the
//! queue name picks the shard). Publishes, acks and delivery pumping for
//! queues in different shards never contend on a lock — the hot path
//! scales with cores instead of serialising on one `Mutex<Core>`.
//!
//! Delivery tags are *stride-encoded*: shard `i` of `N` allocates tags
//! `i + N, i + 2N, i + 3N, …`, so `tag % N` recovers the owning shard.
//! An ack therefore routes straight to the right shard without any shared
//! lookup structure, and each shard keeps its own `delivery_tag → queue`
//! index.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::broker::core::{ConnectionEntry, ConnectionId};
use crate::broker::queue::{PendingDead, Queue};

/// One shard: a lock over its queues, its share of the delivery index, and
/// a cache of connection entries for lock-free-ish delivery sends.
pub struct Shard {
    index: usize,
    state: Mutex<ShardState>,
}

/// The state guarded by one shard lock.
pub struct ShardState {
    /// Queues owned by this shard, keyed by the router-interned name
    /// handle (lookups still take `&str` via `Borrow`).
    pub queues: HashMap<Arc<str>, Queue>,
    /// delivery_tag -> queue name, for tags allocated by this shard.
    /// Entries are pruned on ack/nack, on queue deletion and on connection
    /// disconnect (requeued messages get fresh tags on redelivery).
    /// Values are interned handles: recording a delivery is a refcount
    /// bump, not a `String` allocation.
    pub delivery_index: HashMap<u64, Arc<str>>,
    /// Delivery targets: connections with consumers on this shard's
    /// queues. Populated on `Consume`, pruned on disconnect. Keeping the
    /// `Arc`s here lets the dispatcher send while holding only the shard
    /// lock — no excursion into the global connection registry.
    pub conns: HashMap<ConnectionId, Arc<ConnectionEntry>>,
    index: u64,
    stride: u64,
    next_tag: u64,
}

impl ShardState {
    /// Allocate the next stride-encoded delivery tag for this shard.
    /// (Same allocator the dispatcher borrows via [`ShardState::for_dispatch`].)
    pub fn alloc_tag(&mut self) -> u64 {
        TagAlloc { index: self.index, stride: self.stride, next_tag: &mut self.next_tag }.next()
    }

    /// Drop `conn` from every queue in this shard: requeue its unacked
    /// messages (dead-lettering any over the `max_delivery` cap), remove
    /// its consumers, prune its delivery-index entries (requeued messages
    /// get fresh tags on redelivery, so stale entries would leak forever
    /// under connection churn).
    pub fn drop_connection(&mut self, conn: ConnectionId) -> ShardDropOutcome {
        self.conns.remove(&conn);
        let mut out = ShardDropOutcome::default();
        for (name, q) in self.queues.iter_mut() {
            let dropped = q.drop_connection(conn);
            for t in &dropped.dead_tags {
                self.delivery_index.remove(t);
            }
            if !dropped.dead_tags.is_empty() || q.consumer_count() > 0 {
                out.touched.push(name.clone());
            }
            out.requeued += dropped.dead_tags.len() - dropped.dead.len();
            if !dropped.dead.is_empty() {
                out.dead.extend(q.pend_dead(dropped.dead));
            }
            if q.options.durable && !dropped.requeued.is_empty() {
                out.requeue_log.push((name.clone(), dropped.requeued));
            }
        }
        out
    }

    /// Split the state into the pieces the dispatcher needs with disjoint
    /// borrows: (queues, delivery_index, conns, tag allocator inputs).
    pub fn for_dispatch(
        &mut self,
    ) -> (
        &mut HashMap<Arc<str>, Queue>,
        &mut HashMap<u64, Arc<str>>,
        &HashMap<ConnectionId, Arc<ConnectionEntry>>,
        TagAlloc<'_>,
    ) {
        (
            &mut self.queues,
            &mut self.delivery_index,
            &self.conns,
            TagAlloc { index: self.index, stride: self.stride, next_tag: &mut self.next_tag },
        )
    }
}

/// Aggregate result of dropping a connection from one shard.
#[derive(Default)]
pub struct ShardDropOutcome {
    /// Messages returned to their queues.
    pub requeued: usize,
    /// Queues whose delivery pump should run.
    pub touched: Vec<Arc<str>>,
    /// Messages over their queue's `max_delivery` cap — the core
    /// dead-letters them once no shard lock is held.
    pub dead: Vec<PendingDead>,
    /// Per durable queue: `(msg_id, delivery_count)` requeue log entries
    /// for WAL records (attempt counts survive recovery).
    pub requeue_log: Vec<(Arc<str>, Vec<(u64, u32)>)>,
}

/// A borrowed tag allocator (disjoint from the queue map borrow).
pub struct TagAlloc<'a> {
    index: u64,
    stride: u64,
    next_tag: &'a mut u64,
}

impl TagAlloc<'_> {
    pub fn next(&mut self) -> u64 {
        *self.next_tag += 1;
        self.index + self.stride * *self.next_tag
    }
}

impl Shard {
    fn new(index: usize, stride: usize, tag_origin: u64) -> Self {
        Shard {
            index,
            state: Mutex::new(ShardState {
                queues: HashMap::new(),
                delivery_index: HashMap::new(),
                conns: HashMap::new(),
                index: index as u64,
                stride: stride as u64,
                next_tag: tag_origin,
            }),
        }
    }

    pub fn index(&self) -> usize {
        self.index
    }

    pub fn lock(&self) -> MutexGuard<'_, ShardState> {
        self.state.lock().unwrap()
    }
}

/// Per-boot origin for delivery-tag counters: seconds since the epoch,
/// shifted left 20 bits (≈1M tags of headroom per shard per second of
/// wall-clock separation between boots). A restarted broker therefore
/// issues tags strictly greater than anything a previous boot handed
/// out, so a client holding a tag across the restart can never have its
/// stale ack collide with a freshly issued tag.
pub fn boot_tag_origin() -> u64 {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(1);
    secs << 20
}

/// The fixed set of shards. Shard count is chosen at broker construction
/// and never changes (queue → shard mapping must stay stable).
pub struct ShardSet {
    shards: Vec<Shard>,
}

impl ShardSet {
    /// Tag counters start at 0 — deterministic, for tests and benches.
    pub fn new(n: usize) -> Self {
        Self::with_tag_origin(n, 0)
    }

    /// Tag counters start at `origin`. Real brokers pass
    /// [`boot_tag_origin`] so delivery tags are monotonic *across
    /// restarts*: a tag issued by a previous boot is never reissued by
    /// this one, which is what lets a reconnecting client's stale-tag
    /// guard (`transport/conn.rs`) distinguish pre-outage tags from live
    /// ones by value.
    pub fn with_tag_origin(n: usize, origin: u64) -> Self {
        let n = n.max(1);
        ShardSet { shards: (0..n).map(|i| Shard::new(i, n, origin)).collect() }
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty() // never true: `new` clamps to ≥ 1 shard
    }

    /// Stable queue-name → shard-index mapping.
    pub fn index_for(&self, queue: &str) -> usize {
        let mut h = DefaultHasher::new();
        queue.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }

    pub fn shard_for(&self, queue: &str) -> &Shard {
        &self.shards[self.index_for(queue)]
    }

    /// The shard that allocated `tag` (stride encoding).
    pub fn shard_for_tag(&self, tag: u64) -> &Shard {
        &self.shards[(tag % self.shards.len() as u64) as usize]
    }

    pub fn get(&self, index: usize) -> &Shard {
        &self.shards[index]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Shard> {
        self.shards.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_unique_and_route_back_to_their_shard() {
        let set = ShardSet::new(4);
        let mut seen = std::collections::HashSet::new();
        for shard in set.iter() {
            let mut st = shard.lock();
            for _ in 0..100 {
                let tag = st.alloc_tag();
                assert!(tag > 0, "tags are non-zero");
                assert!(seen.insert(tag), "tags are globally unique");
                assert_eq!(set.shard_for_tag(tag).index(), shard.index());
            }
        }
    }

    #[test]
    fn queue_mapping_is_stable_and_total() {
        let set = ShardSet::new(8);
        for name in ["tasks", "replies", "kiwi.rpc.q", "a", ""] {
            let i = set.index_for(name);
            assert!(i < set.len());
            assert_eq!(i, set.index_for(name), "mapping must be deterministic");
        }
    }

    #[test]
    fn single_shard_set_degenerates_to_global_lock() {
        let set = ShardSet::new(1);
        assert_eq!(set.len(), 1);
        assert_eq!(set.index_for("anything"), 0);
        let mut st = set.get(0).lock();
        assert_eq!(st.alloc_tag(), 1);
        assert_eq!(st.alloc_tag(), 2);
    }

    #[test]
    fn zero_shards_clamped_to_one() {
        assert_eq!(ShardSet::new(0).len(), 1);
    }

    #[test]
    fn tag_origins_keep_boots_disjoint() {
        // A "restarted broker" (later origin) must never reissue a tag
        // value an earlier boot handed out — the client-side stale-tag
        // guard distinguishes pre-outage tags by value.
        let boot1 = ShardSet::with_tag_origin(4, 100);
        let boot2 = ShardSet::with_tag_origin(4, 200);
        let mut first = std::collections::HashSet::new();
        for shard in boot1.iter() {
            let mut st = shard.lock();
            for _ in 0..100 {
                first.insert(st.alloc_tag());
            }
        }
        for shard in boot2.iter() {
            let mut st = shard.lock();
            for _ in 0..100 {
                let tag = st.alloc_tag();
                assert!(!first.contains(&tag), "boot 2 reissued tag {tag}");
                assert_eq!(boot2.shard_for_tag(tag).index(), shard.index());
            }
        }
        // The real origin is wall-clock-derived and strictly positive.
        assert!(boot_tag_origin() > 0);
    }
}
