//! The message broker — the RabbitMQ-equivalent substrate kiwiPy depends
//! on, built from scratch (see DESIGN.md §2 Substitutions).
//!
//! Semantics implemented (the subset kiwiPy's three message types rely on,
//! plus the standard AMQP features around them):
//!
//! * **Queues** with explicit acknowledgement, negative-ack, automatic
//!   redelivery of unacknowledged messages when a consumer dies,
//!   per-consumer prefetch (QoS), FIFO within a priority level, message
//!   priorities, per-message and per-queue TTL, exclusive and auto-delete
//!   queues.
//! * **Exchanges**: direct, fanout and topic (`*` / `#` wildcards).
//! * **At-most-one-consumer delivery**: a ready message is handed to a
//!   single consumer and stays invisible until acked or returned.
//! * **Heartbeats**: connections missing two consecutive heartbeats are
//!   evicted and all their unacked messages requeued — the exact behaviour
//!   the paper highlights.
//! * **Durability**: durable queues persist messages to a write-ahead log
//!   and survive broker restarts.
//!
//! The [`core::BrokerCore`] is transport-agnostic and sharded: [`router`]
//! resolves exchanges/bindings behind read-mostly locks — topic exchanges
//! through a word-trie index with an interned, generation-invalidated
//! route cache in front (a hot-key publish is one cache probe, zero
//! allocations) — [`shard`] holds N independent queue shards (hash of
//! queue name → shard) so traffic to different queues never contends, and
//! [`dispatch`] drains ready messages in batches, coalescing them into
//! per-connection multi-delivery frames.
//! [`server`] exposes the core over TCP — by default through the
//! [`reactor`], a single epoll event loop serving every connection with
//! per-connection outbox backpressure (`KIWI_NET=threads` selects the
//! historical thread-per-connection front-end) — and [`inproc`] embeds it
//! in-process (used by tests, benches and single-machine deployments —
//! AiiDA's "individual laptop" scale).

pub mod core;
pub mod dispatch;
pub mod exchange;
pub mod heartbeat;
pub mod inproc;
pub mod persistence;
pub mod protocol;
pub mod queue;
pub mod reactor;
pub mod router;
pub mod server;
pub mod session;
pub mod shard;

pub use self::core::{
    BrokerConfig, BrokerCore, BrokerHandle, ConnectionId, DeliverySink, Outbound,
};
pub use inproc::InprocBroker;
pub use protocol::{
    ClientRequest, Delivery, EncodedProps, MessageProps, OverflowPolicy, QueueOptions, ServerMsg,
};
pub use reactor::ReactorOptions;
pub use server::{BrokerServer, NetMode, NetOptions};
