//! Client ⇄ broker protocol messages and their wire encodings.
//!
//! Every request carries a client-chosen `req_id`; the broker answers with
//! `Ok {req_id, ..}` or `Err {req_id, ..}`. Deliveries are unsolicited
//! (push) messages tied to a consumer tag, exactly like AMQP's
//! `basic.deliver`.
//!
//! ## Encode-once bodies
//!
//! `Publish`, `Deliver` and `DeliverBatch` carry the message body (and the
//! message props) as opaque [`Bytes`] *sections* appended after the frame's
//! envelope, not as part of its value tree. The publisher encodes the body
//! exactly once; the broker routes on the envelope and props alone and
//! never decodes — or re-encodes — the payload. Consumers decode lazily.

use std::collections::BTreeMap;
use std::ops::Deref;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::wire::{Bytes, Frame, SectionCursor, Value};

/// Message properties (the AMQP `basic.properties` subset kiwiPy uses).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MessageProps {
    /// Correlates an RPC reply with its request.
    pub correlation_id: Option<String>,
    /// Queue the reply should be published to.
    pub reply_to: Option<String>,
    /// Per-message TTL in milliseconds.
    pub expiration_ms: Option<u64>,
    /// 0–9, higher is delivered first (within a queue).
    pub priority: u8,
    /// Persist to the WAL when the queue is durable.
    pub persistent: bool,
    /// Free-form application headers.
    pub headers: BTreeMap<String, Value>,
}

impl MessageProps {
    /// Build the value tree for encoding. Clones the headers map — which is
    /// why the stack carries [`EncodedProps`] (encoded exactly once per
    /// message) instead of calling this per delivery or per WAL record.
    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        if let Some(c) = &self.correlation_id {
            m.insert("correlation_id".into(), Value::str(c));
        }
        if let Some(r) = &self.reply_to {
            m.insert("reply_to".into(), Value::str(r));
        }
        if let Some(e) = self.expiration_ms {
            m.insert("expiration_ms".into(), Value::from(e));
        }
        if self.priority != 0 {
            m.insert("priority".into(), Value::I64(self.priority as i64));
        }
        if self.persistent {
            m.insert("persistent".into(), Value::Bool(true));
        }
        if !self.headers.is_empty() {
            m.insert("headers".into(), Value::Map(self.headers.clone()));
        }
        Value::Map(m)
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let mut p = MessageProps::default();
        if let Some(c) = v.get_opt("correlation_id") {
            p.correlation_id = Some(c.as_str()?.to_string());
        }
        if let Some(r) = v.get_opt("reply_to") {
            p.reply_to = Some(r.as_str()?.to_string());
        }
        if let Some(e) = v.get_opt("expiration_ms") {
            p.expiration_ms = Some(e.as_u64()?);
        }
        if let Some(pr) = v.get_opt("priority") {
            p.priority = pr.as_u64()?.min(9) as u8;
        }
        if let Some(pe) = v.get_opt("persistent") {
            p.persistent = pe.as_bool()?;
        }
        if let Some(h) = v.get_opt("headers") {
            p.headers = h.as_map()?.clone();
        }
        Ok(p)
    }
}

/// [`MessageProps`] paired with their canonical encoding.
///
/// The encoding is produced exactly once — at the publisher, or captured
/// verbatim off the wire — and then shared by refcount across queue
/// copies, every fanout delivery and every WAL record. This is what kills
/// the per-delivery `headers.clone()` that used to run on each encode.
#[derive(Clone, Debug)]
pub struct EncodedProps {
    props: Arc<MessageProps>,
    bytes: Bytes,
}

impl EncodedProps {
    /// Encode `props` (the single encode of these props' lifetime).
    pub fn new(props: MessageProps) -> Self {
        let bytes = Bytes::encode(&props.to_value());
        EncodedProps { props: Arc::new(props), bytes }
    }

    /// Adopt canonical bytes received off the wire — decodes for local
    /// field access, re-encodes nothing.
    pub fn from_wire(bytes: Bytes) -> Result<Self> {
        let props = MessageProps::from_value(&bytes.decode()?)?;
        Ok(EncodedProps { props: Arc::new(props), bytes })
    }

    pub fn props(&self) -> &MessageProps {
        &self.props
    }

    /// The cached canonical encoding.
    pub fn bytes(&self) -> &Bytes {
        &self.bytes
    }

    /// Re-home the cached encoding into its own minimal buffer.
    ///
    /// Off the wire, `bytes` is a view of the whole receive frame (and on
    /// replay, of a WAL record buffer) — copies held for the life of a
    /// durable message (e.g. the WAL shadow) must detach or they pin the
    /// entire source allocation.
    pub fn detach(&self) -> Self {
        EncodedProps { props: Arc::clone(&self.props), bytes: self.bytes.detach() }
    }
}

impl Deref for EncodedProps {
    type Target = MessageProps;

    fn deref(&self) -> &MessageProps {
        &self.props
    }
}

impl From<MessageProps> for EncodedProps {
    fn from(props: MessageProps) -> Self {
        EncodedProps::new(props)
    }
}

impl Default for EncodedProps {
    fn default() -> Self {
        EncodedProps::new(MessageProps::default())
    }
}

impl PartialEq for EncodedProps {
    fn eq(&self, other: &Self) -> bool {
        self.props == other.props
    }
}

/// Exchange types (mirrors AMQP).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeKind {
    /// Route on exact `routing_key` match.
    Direct,
    /// Route to every bound queue.
    Fanout,
    /// Route on dotted-pattern match with `*` (one word) / `#` (≥0 words).
    Topic,
}

impl ExchangeKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ExchangeKind::Direct => "direct",
            ExchangeKind::Fanout => "fanout",
            ExchangeKind::Topic => "topic",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "direct" => Ok(ExchangeKind::Direct),
            "fanout" => Ok(ExchangeKind::Fanout),
            "topic" => Ok(ExchangeKind::Topic),
            other => Err(Error::Wire(format!("unknown exchange kind '{other}'"))),
        }
    }
}

/// What a queue at `max_length` does with the overflow (mirrors RabbitMQ's
/// `x-overflow`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Drop the *oldest* ready message to make room (dead-lettering it
    /// when the queue has a DLX). RabbitMQ's default.
    #[default]
    DropHead,
    /// Refuse the *incoming* message instead (dead-lettering it when the
    /// queue has a DLX) — backpressure on publishers rather than silent
    /// loss of queued work.
    RejectNew,
}

impl OverflowPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            OverflowPolicy::DropHead => "drop-head",
            OverflowPolicy::RejectNew => "reject-new",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "drop-head" => Ok(OverflowPolicy::DropHead),
            "reject-new" => Ok(OverflowPolicy::RejectNew),
            other => Err(Error::Wire(format!("unknown overflow policy '{other}'"))),
        }
    }
}

/// Options for queue declaration.
#[derive(Clone, Debug, PartialEq)]
pub struct QueueOptions {
    /// Survive broker restart (messages go through the WAL).
    pub durable: bool,
    /// Only the declaring connection may consume; deleted when it closes.
    pub exclusive: bool,
    /// Delete when the last consumer cancels.
    pub auto_delete: bool,
    /// Default TTL applied to messages without their own expiration.
    pub default_ttl_ms: Option<u64>,
    /// Maximum queue length; what happens beyond it is [`OverflowPolicy`].
    pub max_length: Option<usize>,
    /// Overflow behaviour once `max_length` is reached.
    pub overflow: OverflowPolicy,
    /// Max delivery attempts per message; a message nack-requeued at this
    /// count is dead-lettered instead of requeued (poison-message cap).
    /// `None` = unlimited (seed behaviour: a poison task redelivers
    /// forever).
    pub max_delivery: Option<u32>,
    /// Dead-letter exchange: rejected, max-redelivered, expired and
    /// overflowed messages are re-published here instead of vanishing.
    pub dead_letter_exchange: Option<String>,
    /// Routing key for dead-letter re-publishes; `None` keeps the
    /// message's original routing key.
    pub dead_letter_routing_key: Option<String>,
    /// Stream queue: an append-only log instead of a destructive work
    /// queue. Consumers attach with [`ClientRequest::StreamConsume`] at an
    /// offset; acks advance their group's committed cursor instead of
    /// deleting the message, so any number of groups can replay the same
    /// log independently. `max_length`/`overflow`/TTL/DLX options do not
    /// apply — retention truncates whole segments by age/size instead.
    pub stream: bool,
    /// Number of partitions a stream's offsets are assigned over inside a
    /// consumer group (offset % partitions → group member). 0 = broker
    /// default. Ignored for non-stream queues.
    pub partitions: u32,
}

impl Default for QueueOptions {
    fn default() -> Self {
        QueueOptions {
            durable: false,
            exclusive: false,
            auto_delete: false,
            default_ttl_ms: None,
            max_length: None,
            overflow: OverflowPolicy::DropHead,
            max_delivery: None,
            dead_letter_exchange: None,
            dead_letter_routing_key: None,
            stream: false,
            partitions: 0,
        }
    }
}

impl QueueOptions {
    pub fn durable() -> Self {
        QueueOptions { durable: true, ..Default::default() }
    }

    /// A stream queue (append-only log with cursor-based consumers).
    pub fn stream() -> Self {
        QueueOptions { stream: true, ..Default::default() }
    }

    pub fn to_value(&self) -> Value {
        Value::map([
            ("durable", Value::Bool(self.durable)),
            ("exclusive", Value::Bool(self.exclusive)),
            ("auto_delete", Value::Bool(self.auto_delete)),
            ("default_ttl_ms", self.default_ttl_ms.into()),
            ("max_length", self.max_length.map(|n| n as u64).into()),
            ("overflow", Value::str(self.overflow.as_str())),
            ("max_delivery", self.max_delivery.map(u64::from).into()),
            ("dead_letter_exchange", self.dead_letter_exchange.clone().into()),
            ("dead_letter_routing_key", self.dead_letter_routing_key.clone().into()),
            ("stream", Value::Bool(self.stream)),
            ("partitions", Value::from(u64::from(self.partitions))),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(QueueOptions {
            durable: v.get_opt("durable").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
            exclusive: v.get_opt("exclusive").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
            auto_delete: v
                .get_opt("auto_delete")
                .map(|b| b.as_bool())
                .transpose()?
                .unwrap_or(false),
            default_ttl_ms: v.get_opt("default_ttl_ms").map(|x| x.as_u64()).transpose()?,
            max_length: v
                .get_opt("max_length")
                .map(|x| x.as_u64().map(|n| n as usize))
                .transpose()?,
            // Absent on pre-lifecycle records (old WALs, old clients):
            // default to the seed behaviour.
            overflow: v
                .get_opt("overflow")
                .map(|x| x.as_str().and_then(OverflowPolicy::parse))
                .transpose()?
                .unwrap_or_default(),
            max_delivery: v
                .get_opt("max_delivery")
                .map(|x| x.as_u64().map(|n| n as u32))
                .transpose()?,
            dead_letter_exchange: v
                .get_opt("dead_letter_exchange")
                .map(|x| x.as_str().map(String::from))
                .transpose()?,
            dead_letter_routing_key: v
                .get_opt("dead_letter_routing_key")
                .map(|x| x.as_str().map(String::from))
                .transpose()?,
            // Absent on pre-stream records/clients: a plain work queue.
            stream: v.get_opt("stream").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
            partitions: v
                .get_opt("partitions")
                .map(|x| x.as_u64().map(|n| n as u32))
                .transpose()?
                .unwrap_or(0),
        })
    }
}

/// Requests a client may send.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientRequest {
    /// First frame on a connection; sets identity and heartbeat interval.
    Hello { client_id: String, heartbeat_ms: u64 },
    QueueDeclare { queue: String, options: QueueOptions },
    QueueDelete { queue: String },
    QueuePurge { queue: String },
    ExchangeDeclare { exchange: String, kind: ExchangeKind },
    Bind { exchange: String, queue: String, routing_key: String },
    Unbind { exchange: String, queue: String, routing_key: String },
    Publish {
        /// Empty string = default exchange (routes directly to the queue
        /// named by `routing_key`), as in AMQP.
        exchange: String,
        routing_key: String,
        /// The body, encoded exactly once by the publisher. Opaque to the
        /// broker; travels as a trailing frame section.
        body: Bytes,
        props: EncodedProps,
        /// When true and the message routes to zero queues, the broker
        /// answers with an `unroutable` error instead of dropping it.
        mandatory: bool,
    },
    Consume { queue: String, consumer_tag: String, prefetch: u32 },
    /// Attach a cursor-based consumer to a stream queue as a member of
    /// `group`. All members of one group share a cursor and a committed
    /// offset; each stream entry is delivered to exactly one member
    /// (partitioned by `offset % partitions`). Distinct groups replay the
    /// log independently.
    StreamConsume {
        queue: String,
        consumer_tag: String,
        /// Consumer-group name. Groups are created on first attach.
        group: String,
        prefetch: u32,
        /// Seek: start replay at this offset. `None` resumes from the
        /// group's committed offset (a brand-new group starts at the tail
        /// of what retention still holds).
        offset: Option<u64>,
    },
    /// Explicitly commit a group's consumed offset on a stream (offsets up
    /// to and including `offset` are marked consumed). Normally the commit
    /// rides the regular ack frames; this frame is the seek/replay
    /// escape hatch.
    StreamCommit { queue: String, group: String, offset: u64 },
    Cancel { consumer_tag: String },
    Ack { delivery_tag: u64 },
    /// Acknowledge many deliveries in one frame (the client-side ack
    /// pipeline coalesces acks issued while a delivery batch is being
    /// dispatched). Each tag is acked independently and idempotently.
    AckMulti { delivery_tags: Vec<u64> },
    Nack { delivery_tag: u64, requeue: bool },
    /// Negative-acknowledge many deliveries in one frame (same coalescing
    /// rationale as `AckMulti`). Each tag is handled independently and
    /// idempotently; `requeue` applies to all of them.
    NackMulti { delivery_tags: Vec<u64>, requeue: bool },
    /// AMQP `basic.reject`: refuse a single delivery. Semantically
    /// identical to `Nack` with one tag; kept as its own frame for
    /// protocol parity with AMQP clients.
    Reject { delivery_tag: u64, requeue: bool },
    /// Broker status snapshot (queue depths, counters).
    Status,
    Close,
}

/// An unsolicited message delivery (broker → consumer).
#[derive(Clone, Debug, PartialEq)]
pub struct Delivery {
    pub consumer_tag: String,
    pub delivery_tag: u64,
    pub redelivered: bool,
    pub exchange: Arc<str>,
    pub routing_key: Arc<str>,
    /// The publisher's encoded body — shared by refcount all the way from
    /// the publish; decode at the consumer with [`Bytes::decode`].
    ///
    /// Note: on the TCP read side, every delivery of a coalesced
    /// `DeliverBatch` is a view of the *one* frame receive buffer, so
    /// retaining a single delivery long-term pins the whole batch's
    /// allocation — call [`Bytes::detach`] when storing bodies beyond the
    /// handler's scope.
    pub body: Bytes,
    pub props: EncodedProps,
    /// Stream queues only: the entry's log offset (commit `offset` to mark
    /// everything up to and including it consumed). `None` on work-queue
    /// deliveries and on frames from pre-stream brokers.
    pub offset: Option<u64>,
}

/// Messages the broker sends to a client.
#[derive(Clone, Debug, PartialEq)]
pub enum ServerMsg {
    Ok { req_id: u64, reply: Value },
    Err { req_id: u64, code: String, message: String },
    Deliver(Delivery),
    /// Several deliveries coalesced into one frame by the batched
    /// dispatcher — one channel-send / one syscall for the whole batch.
    /// Clients dispatch the contained deliveries in order.
    DeliverBatch(Vec<Delivery>),
    /// Consumer cancelled server-side (queue deleted / exclusivity).
    CancelConsumer { consumer_tag: String },
    /// Publish-credit grant (broker → publisher flow control). The broker
    /// decrements the connection's credit per publish and re-grants when
    /// the target queues have drained below their low-water mark; a client
    /// at zero credit blocks its publishers (bounded) instead of flooding
    /// a broker that is paging queue tails to disk. Connections that never
    /// receive a grant are uncredited (unlimited) — old brokers keep
    /// working with new clients and vice versa.
    Credit { channel_credit: u32 },
}

fn req(op: &str, req_id: u64, fields: Vec<(&str, Value)>) -> Value {
    let mut m: BTreeMap<String, Value> =
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    m.insert("op".into(), Value::str(op));
    m.insert("req_id".into(), Value::from(req_id));
    Value::Map(m)
}

impl ClientRequest {
    /// Encode into a frame with a request id. Payload-carrying requests
    /// attach their props/body bytes as sections; everything else is a
    /// plain envelope frame.
    pub fn to_frame(&self, req_id: u64) -> Frame {
        match self {
            ClientRequest::Publish { exchange, routing_key, body, props, mandatory } => {
                let envelope = req(
                    "publish",
                    req_id,
                    vec![
                        ("exchange", Value::str(exchange)),
                        ("routing_key", Value::str(routing_key)),
                        ("mandatory", Value::Bool(*mandatory)),
                        ("props_len", Value::from(props.bytes().len())),
                        ("body_len", Value::from(body.len())),
                    ],
                );
                Frame::data_with_sections(&envelope, vec![props.bytes().clone(), body.clone()])
            }
            other => Frame::data(&other.control_value(req_id)),
        }
    }

    /// Envelope encoding for requests that carry no byte sections.
    fn control_value(&self, req_id: u64) -> Value {
        match self {
            ClientRequest::Hello { client_id, heartbeat_ms } => req(
                "hello",
                req_id,
                vec![
                    ("client_id", Value::str(client_id)),
                    ("heartbeat_ms", Value::from(*heartbeat_ms)),
                ],
            ),
            ClientRequest::QueueDeclare { queue, options } => req(
                "queue_declare",
                req_id,
                vec![("queue", Value::str(queue)), ("options", options.to_value())],
            ),
            ClientRequest::QueueDelete { queue } => {
                req("queue_delete", req_id, vec![("queue", Value::str(queue))])
            }
            ClientRequest::QueuePurge { queue } => {
                req("queue_purge", req_id, vec![("queue", Value::str(queue))])
            }
            ClientRequest::ExchangeDeclare { exchange, kind } => req(
                "exchange_declare",
                req_id,
                vec![("exchange", Value::str(exchange)), ("kind", Value::str(kind.as_str()))],
            ),
            ClientRequest::Bind { exchange, queue, routing_key } => req(
                "bind",
                req_id,
                vec![
                    ("exchange", Value::str(exchange)),
                    ("queue", Value::str(queue)),
                    ("routing_key", Value::str(routing_key)),
                ],
            ),
            ClientRequest::Unbind { exchange, queue, routing_key } => req(
                "unbind",
                req_id,
                vec![
                    ("exchange", Value::str(exchange)),
                    ("queue", Value::str(queue)),
                    ("routing_key", Value::str(routing_key)),
                ],
            ),
            ClientRequest::Publish { .. } => {
                unreachable!("publish frames carry sections; encoded in to_frame")
            }
            ClientRequest::Consume { queue, consumer_tag, prefetch } => req(
                "consume",
                req_id,
                vec![
                    ("queue", Value::str(queue)),
                    ("consumer_tag", Value::str(consumer_tag)),
                    ("prefetch", Value::from(*prefetch as u64)),
                ],
            ),
            ClientRequest::StreamConsume { queue, consumer_tag, group, prefetch, offset } => req(
                "stream_consume",
                req_id,
                vec![
                    ("queue", Value::str(queue)),
                    ("consumer_tag", Value::str(consumer_tag)),
                    ("group", Value::str(group)),
                    ("prefetch", Value::from(*prefetch as u64)),
                    ("offset", (*offset).into()),
                ],
            ),
            ClientRequest::StreamCommit { queue, group, offset } => req(
                "stream_commit",
                req_id,
                vec![
                    ("queue", Value::str(queue)),
                    ("group", Value::str(group)),
                    ("offset", Value::from(*offset)),
                ],
            ),
            ClientRequest::Cancel { consumer_tag } => {
                req("cancel", req_id, vec![("consumer_tag", Value::str(consumer_tag))])
            }
            ClientRequest::Ack { delivery_tag } => {
                req("ack", req_id, vec![("delivery_tag", Value::from(*delivery_tag))])
            }
            ClientRequest::AckMulti { delivery_tags } => req(
                "ack_multi",
                req_id,
                vec![(
                    "delivery_tags",
                    Value::List(delivery_tags.iter().map(|t| Value::from(*t)).collect()),
                )],
            ),
            ClientRequest::Nack { delivery_tag, requeue } => req(
                "nack",
                req_id,
                vec![
                    ("delivery_tag", Value::from(*delivery_tag)),
                    ("requeue", Value::Bool(*requeue)),
                ],
            ),
            ClientRequest::NackMulti { delivery_tags, requeue } => req(
                "nack_multi",
                req_id,
                vec![
                    (
                        "delivery_tags",
                        Value::List(delivery_tags.iter().map(|t| Value::from(*t)).collect()),
                    ),
                    ("requeue", Value::Bool(*requeue)),
                ],
            ),
            ClientRequest::Reject { delivery_tag, requeue } => req(
                "reject",
                req_id,
                vec![
                    ("delivery_tag", Value::from(*delivery_tag)),
                    ("requeue", Value::Bool(*requeue)),
                ],
            ),
            ClientRequest::Status => req("status", req_id, vec![]),
            ClientRequest::Close => req("close", req_id, vec![]),
        }
    }

    /// Decode a frame; returns `(request, req_id)`. A publish's props and
    /// body come back as refcounted views of the frame's buffers — nothing
    /// is copied or re-encoded.
    pub fn from_frame(frame: &Frame) -> Result<(Self, u64)> {
        let (v, mut sections) = frame.open()?;
        let req_id = v.get_u64("req_id")?;
        let op = v.get_str("op")?;
        if op == "publish" {
            let props_len = v.get_u64("props_len")? as usize;
            let body_len = v.get_u64("body_len")? as usize;
            let props = EncodedProps::from_wire(sections.take(props_len)?)?;
            let body = sections.take(body_len)?;
            sections.finish()?;
            let request = ClientRequest::Publish {
                exchange: v.get_str("exchange")?.to_string(),
                routing_key: v.get_str("routing_key")?.to_string(),
                body,
                props,
                mandatory: v.get_bool("mandatory")?,
            };
            return Ok((request, req_id));
        }
        sections.finish()?;
        let r = match op {
            "hello" => ClientRequest::Hello {
                client_id: v.get_str("client_id")?.to_string(),
                heartbeat_ms: v.get_u64("heartbeat_ms")?,
            },
            "queue_declare" => ClientRequest::QueueDeclare {
                queue: v.get_str("queue")?.to_string(),
                options: QueueOptions::from_value(v.get("options")?)?,
            },
            "queue_delete" => ClientRequest::QueueDelete { queue: v.get_str("queue")?.to_string() },
            "queue_purge" => ClientRequest::QueuePurge { queue: v.get_str("queue")?.to_string() },
            "exchange_declare" => ClientRequest::ExchangeDeclare {
                exchange: v.get_str("exchange")?.to_string(),
                kind: ExchangeKind::parse(v.get_str("kind")?)?,
            },
            "bind" => ClientRequest::Bind {
                exchange: v.get_str("exchange")?.to_string(),
                queue: v.get_str("queue")?.to_string(),
                routing_key: v.get_str("routing_key")?.to_string(),
            },
            "unbind" => ClientRequest::Unbind {
                exchange: v.get_str("exchange")?.to_string(),
                queue: v.get_str("queue")?.to_string(),
                routing_key: v.get_str("routing_key")?.to_string(),
            },
            "consume" => ClientRequest::Consume {
                queue: v.get_str("queue")?.to_string(),
                consumer_tag: v.get_str("consumer_tag")?.to_string(),
                prefetch: v.get_u64("prefetch")? as u32,
            },
            "stream_consume" => ClientRequest::StreamConsume {
                queue: v.get_str("queue")?.to_string(),
                consumer_tag: v.get_str("consumer_tag")?.to_string(),
                group: v.get_str("group")?.to_string(),
                prefetch: v.get_u64("prefetch")? as u32,
                offset: v.get_opt("offset").map(|x| x.as_u64()).transpose()?,
            },
            "stream_commit" => ClientRequest::StreamCommit {
                queue: v.get_str("queue")?.to_string(),
                group: v.get_str("group")?.to_string(),
                offset: v.get_u64("offset")?,
            },
            "cancel" => {
                ClientRequest::Cancel { consumer_tag: v.get_str("consumer_tag")?.to_string() }
            }
            "ack" => ClientRequest::Ack { delivery_tag: v.get_u64("delivery_tag")? },
            "ack_multi" => ClientRequest::AckMulti {
                delivery_tags: v
                    .get("delivery_tags")?
                    .as_list()?
                    .iter()
                    .map(|t| t.as_u64())
                    .collect::<Result<Vec<u64>>>()?,
            },
            "nack" => ClientRequest::Nack {
                delivery_tag: v.get_u64("delivery_tag")?,
                requeue: v.get_bool("requeue")?,
            },
            "nack_multi" => ClientRequest::NackMulti {
                delivery_tags: v
                    .get("delivery_tags")?
                    .as_list()?
                    .iter()
                    .map(|t| t.as_u64())
                    .collect::<Result<Vec<u64>>>()?,
                requeue: v.get_bool("requeue")?,
            },
            "reject" => ClientRequest::Reject {
                delivery_tag: v.get_u64("delivery_tag")?,
                requeue: v.get_bool("requeue")?,
            },
            "status" => ClientRequest::Status,
            "close" => ClientRequest::Close,
            other => return Err(Error::Wire(format!("unknown op '{other}'"))),
        };
        Ok((r, req_id))
    }
}

impl Delivery {
    /// The envelope map: everything except the props/body bytes, whose
    /// lengths it declares.
    fn envelope(&self) -> Value {
        Value::map([
            ("kind", Value::str("deliver")),
            ("consumer_tag", Value::str(&self.consumer_tag)),
            ("delivery_tag", Value::from(self.delivery_tag)),
            ("redelivered", Value::Bool(self.redelivered)),
            ("exchange", Value::str(self.exchange.as_ref())),
            ("routing_key", Value::str(self.routing_key.as_ref())),
            ("props_len", Value::from(self.props.bytes().len())),
            ("body_len", Value::from(self.body.len())),
            ("offset", self.offset.into()),
        ])
    }

    /// Append this delivery's sections in wire order (props, then body).
    fn push_sections(&self, out: &mut Vec<Bytes>) {
        out.push(self.props.bytes().clone());
        out.push(self.body.clone());
    }

    /// Rebuild from an envelope plus the frame's section cursor. When
    /// `prev` (the previously decoded delivery of the same batch) carries
    /// the same exchange / routing key — the overwhelmingly common case
    /// for a batch drained from one queue — its `Arc<str>` handles are
    /// reused instead of allocating fresh strings per delivery.
    fn from_envelope(
        v: &Value,
        sections: &mut SectionCursor,
        prev: Option<&Delivery>,
    ) -> Result<Self> {
        let props_len = v.get_u64("props_len")? as usize;
        let body_len = v.get_u64("body_len")? as usize;
        let props = EncodedProps::from_wire(sections.take(props_len)?)?;
        let body = sections.take(body_len)?;
        let exchange_str = v.get_str("exchange")?;
        let exchange: Arc<str> = match prev {
            Some(p) if &*p.exchange == exchange_str => Arc::clone(&p.exchange),
            _ => exchange_str.into(),
        };
        let routing_key_str = v.get_str("routing_key")?;
        let routing_key: Arc<str> = match prev {
            Some(p) if &*p.routing_key == routing_key_str => Arc::clone(&p.routing_key),
            _ => routing_key_str.into(),
        };
        Ok(Delivery {
            consumer_tag: v.get_str("consumer_tag")?.to_string(),
            delivery_tag: v.get_u64("delivery_tag")?,
            redelivered: v.get_bool("redelivered")?,
            exchange,
            routing_key,
            body,
            props,
            offset: v.get_opt("offset").map(|x| x.as_u64()).transpose()?,
        })
    }
}

impl ServerMsg {
    /// Encode into a frame. Deliveries attach their props/body bytes as
    /// sections (one contiguous run per delivery, batch sections in
    /// delivery order); control messages are plain envelope frames.
    pub fn to_frame(&self) -> Frame {
        match self {
            ServerMsg::Deliver(d) => {
                let mut sections = Vec::with_capacity(2);
                d.push_sections(&mut sections);
                Frame::data_with_sections(&d.envelope(), sections)
            }
            ServerMsg::DeliverBatch(ds) => {
                let envelope = Value::map([
                    ("kind", Value::str("deliver_batch")),
                    ("deliveries", Value::List(ds.iter().map(Delivery::envelope).collect())),
                ]);
                let mut sections = Vec::with_capacity(2 * ds.len());
                for d in ds {
                    d.push_sections(&mut sections);
                }
                Frame::data_with_sections(&envelope, sections)
            }
            other => Frame::data(&other.control_value()),
        }
    }

    /// Envelope encoding for messages that carry no byte sections.
    fn control_value(&self) -> Value {
        match self {
            ServerMsg::Ok { req_id, reply } => Value::map([
                ("kind", Value::str("ok")),
                ("req_id", Value::from(*req_id)),
                ("reply", reply.clone()),
            ]),
            ServerMsg::Err { req_id, code, message } => Value::map([
                ("kind", Value::str("err")),
                ("req_id", Value::from(*req_id)),
                ("code", Value::str(code)),
                ("message", Value::str(message)),
            ]),
            ServerMsg::Deliver(_) | ServerMsg::DeliverBatch(_) => {
                unreachable!("delivery frames carry sections; encoded in to_frame")
            }
            ServerMsg::CancelConsumer { consumer_tag } => Value::map([
                ("kind", Value::str("cancel_consumer")),
                ("consumer_tag", Value::str(consumer_tag)),
            ]),
            ServerMsg::Credit { channel_credit } => Value::map([
                ("kind", Value::str("credit")),
                ("channel_credit", Value::from(u64::from(*channel_credit))),
            ]),
        }
    }

    pub fn from_frame(frame: &Frame) -> Result<Self> {
        let (v, mut sections) = frame.open()?;
        match v.get_str("kind")? {
            "deliver" => {
                let d = Delivery::from_envelope(&v, &mut sections, None)?;
                sections.finish()?;
                Ok(ServerMsg::Deliver(d))
            }
            "deliver_batch" => {
                let list = v.get("deliveries")?.as_list()?;
                let mut ds: Vec<Delivery> = Vec::with_capacity(list.len());
                for item in list {
                    let d = Delivery::from_envelope(item, &mut sections, ds.last())?;
                    ds.push(d);
                }
                sections.finish()?;
                Ok(ServerMsg::DeliverBatch(ds))
            }
            "ok" => {
                sections.finish()?;
                Ok(ServerMsg::Ok { req_id: v.get_u64("req_id")?, reply: v.get("reply")?.clone() })
            }
            "err" => {
                sections.finish()?;
                Ok(ServerMsg::Err {
                    req_id: v.get_u64("req_id")?,
                    code: v.get_str("code")?.to_string(),
                    message: v.get_str("message")?.to_string(),
                })
            }
            "cancel_consumer" => {
                sections.finish()?;
                Ok(ServerMsg::CancelConsumer {
                    consumer_tag: v.get_str("consumer_tag")?.to_string(),
                })
            }
            "credit" => {
                sections.finish()?;
                Ok(ServerMsg::Credit { channel_credit: v.get_u64("channel_credit")? as u32 })
            }
            other => Err(Error::Wire(format!("unknown server msg kind '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::{read_frame, write_frame};
    use std::io::Cursor;

    /// Roundtrip a request both in-process (attached sections) and through
    /// a byte stream (sections sliced out of one receive buffer).
    fn roundtrip_req(r: ClientRequest) {
        let frame = r.to_frame(42);
        let (back, id) = ClientRequest::from_frame(&frame).unwrap();
        assert_eq!(id, 42);
        assert_eq!(back, r);

        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let read = read_frame(&mut Cursor::new(&buf)).unwrap();
        let (back, id) = ClientRequest::from_frame(&read).unwrap();
        assert_eq!(id, 42);
        assert_eq!(back, r);
    }

    fn roundtrip_msg(m: ServerMsg) {
        let frame = m.to_frame();
        assert_eq!(ServerMsg::from_frame(&frame).unwrap(), m);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let read = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(ServerMsg::from_frame(&read).unwrap(), m);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(ClientRequest::Hello { client_id: "w1".into(), heartbeat_ms: 500 });
        roundtrip_req(ClientRequest::QueueDeclare {
            queue: "tasks".into(),
            options: QueueOptions {
                durable: true,
                exclusive: false,
                auto_delete: true,
                default_ttl_ms: Some(1000),
                max_length: Some(100),
                overflow: OverflowPolicy::RejectNew,
                max_delivery: Some(5),
                dead_letter_exchange: Some("dlx".into()),
                dead_letter_routing_key: Some("dead.tasks".into()),
                stream: false,
                partitions: 0,
            },
        });
        roundtrip_req(ClientRequest::QueueDeclare {
            queue: "events.log".into(),
            options: QueueOptions {
                durable: true,
                partitions: 4,
                ..QueueOptions::stream()
            },
        });
        roundtrip_req(ClientRequest::ExchangeDeclare {
            exchange: "bc".into(),
            kind: ExchangeKind::Fanout,
        });
        roundtrip_req(ClientRequest::Bind {
            exchange: "rpc".into(),
            queue: "q".into(),
            routing_key: "proc.123".into(),
        });
        roundtrip_req(ClientRequest::Publish {
            exchange: "".into(),
            routing_key: "tasks".into(),
            body: Bytes::encode(&Value::map([("x", Value::I64(1))])),
            props: MessageProps {
                correlation_id: Some("c1".into()),
                reply_to: Some("replies".into()),
                expiration_ms: Some(5000),
                priority: 7,
                persistent: true,
                headers: [("sender".to_string(), Value::str("me"))].into_iter().collect(),
            }
            .into(),
            mandatory: true,
        });
        roundtrip_req(ClientRequest::Consume {
            queue: "tasks".into(),
            consumer_tag: "ct-1".into(),
            prefetch: 1,
        });
        roundtrip_req(ClientRequest::StreamConsume {
            queue: "events.log".into(),
            consumer_tag: "ct-2".into(),
            group: "analytics".into(),
            prefetch: 64,
            offset: Some(12345),
        });
        roundtrip_req(ClientRequest::StreamConsume {
            queue: "events.log".into(),
            consumer_tag: "ct-3".into(),
            group: "audit".into(),
            prefetch: 0,
            offset: None,
        });
        roundtrip_req(ClientRequest::StreamCommit {
            queue: "events.log".into(),
            group: "analytics".into(),
            offset: 777,
        });
        roundtrip_req(ClientRequest::Ack { delivery_tag: 99 });
        roundtrip_req(ClientRequest::AckMulti { delivery_tags: vec![3, 5, 8, 13] });
        roundtrip_req(ClientRequest::AckMulti { delivery_tags: vec![] });
        roundtrip_req(ClientRequest::Nack { delivery_tag: 100, requeue: true });
        roundtrip_req(ClientRequest::NackMulti { delivery_tags: vec![2, 4, 6], requeue: false });
        roundtrip_req(ClientRequest::NackMulti { delivery_tags: vec![], requeue: true });
        roundtrip_req(ClientRequest::Reject { delivery_tag: 11, requeue: false });
        roundtrip_req(ClientRequest::Status);
        roundtrip_req(ClientRequest::Close);
    }

    #[test]
    fn queue_options_lifecycle_fields_default_when_absent() {
        // Old clients / pre-lifecycle WAL records omit the new fields —
        // decoding must fall back to seed behaviour, not error.
        let legacy = Value::map([
            ("durable", Value::Bool(true)),
            ("max_length", Value::from(8u64)),
        ]);
        let opts = QueueOptions::from_value(&legacy).unwrap();
        assert!(opts.durable);
        assert_eq!(opts.max_length, Some(8));
        assert_eq!(opts.overflow, OverflowPolicy::DropHead);
        assert_eq!(opts.max_delivery, None);
        assert_eq!(opts.dead_letter_exchange, None);
        assert_eq!(opts.dead_letter_routing_key, None);
        assert!(!opts.stream);
        assert_eq!(opts.partitions, 0);
    }

    #[test]
    fn overflow_policy_parses_and_rejects_unknown() {
        for p in [OverflowPolicy::DropHead, OverflowPolicy::RejectNew] {
            assert_eq!(OverflowPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(OverflowPolicy::parse("explode").is_err());
    }

    #[test]
    fn server_msgs_roundtrip() {
        for m in [
            ServerMsg::Ok { req_id: 1, reply: Value::Null },
            ServerMsg::Err { req_id: 2, code: "broker".into(), message: "no such queue".into() },
            ServerMsg::Deliver(Delivery {
                consumer_tag: "ct".into(),
                delivery_tag: 7,
                redelivered: true,
                exchange: "".into(),
                routing_key: "tasks".into(),
                body: Bytes::encode(&Value::str("payload")),
                props: MessageProps::default().into(),
                offset: None,
            }),
            ServerMsg::Deliver(Delivery {
                consumer_tag: "ct-s".into(),
                delivery_tag: 8,
                redelivered: false,
                exchange: "".into(),
                routing_key: "events.log".into(),
                body: Bytes::encode(&Value::str("entry")),
                props: MessageProps::default().into(),
                offset: Some(4096),
            }),
            ServerMsg::DeliverBatch(
                (0..3)
                    .map(|i| Delivery {
                        consumer_tag: "ct".into(),
                        delivery_tag: i,
                        redelivered: false,
                        exchange: "".into(),
                        routing_key: "tasks".into(),
                        body: Bytes::encode(&Value::I64(i as i64)),
                        props: MessageProps {
                            priority: (i % 3) as u8,
                            ..Default::default()
                        }
                        .into(),
                        offset: None,
                    })
                    .collect(),
            ),
            ServerMsg::CancelConsumer { consumer_tag: "ct".into() },
            ServerMsg::Credit { channel_credit: 512 },
            ServerMsg::Credit { channel_credit: 0 },
        ] {
            roundtrip_msg(m);
        }
    }

    #[test]
    fn detached_props_leave_the_source_buffer() {
        let props: EncodedProps = MessageProps { priority: 4, ..Default::default() }.into();
        let det = props.detach();
        assert_eq!(det, props);
        assert_eq!(det.bytes().as_slice(), props.bytes().as_slice());
        assert!(
            !Bytes::same_buffer(det.bytes(), props.bytes()),
            "detach must re-home the encoding into its own allocation"
        );
    }

    #[test]
    fn publish_body_is_never_reencoded() {
        // The encode-once pin at the protocol layer: the body bytes inside
        // a locally decoded publish are the very buffer the caller encoded.
        let body = Bytes::encode(&Value::Bytes(vec![0xAB; 4096]));
        let props: EncodedProps = MessageProps { priority: 3, ..Default::default() }.into();
        let r = ClientRequest::Publish {
            exchange: "".into(),
            routing_key: "q".into(),
            body: body.clone(),
            props: props.clone(),
            mandatory: false,
        };
        let frame = r.to_frame(1);
        let (back, _) = ClientRequest::from_frame(&frame).unwrap();
        let ClientRequest::Publish { body: got_body, props: got_props, .. } = back else {
            panic!("expected publish");
        };
        assert!(Bytes::same_buffer(&got_body, &body));
        assert!(Bytes::same_buffer(got_props.bytes(), props.bytes()));
    }

    #[test]
    fn deliver_batch_sections_share_one_receive_buffer() {
        let batch = ServerMsg::DeliverBatch(
            (0..4)
                .map(|i| Delivery {
                    consumer_tag: "ct".into(),
                    delivery_tag: i,
                    redelivered: false,
                    exchange: "".into(),
                    routing_key: "q".into(),
                    body: Bytes::encode(&Value::Bytes(vec![i as u8; 256])),
                    props: MessageProps::default().into(),
                    offset: None,
                })
                .collect(),
        );
        let mut buf = Vec::new();
        write_frame(&mut buf, &batch.to_frame()).unwrap();
        let read = read_frame(&mut Cursor::new(&buf)).unwrap();
        let ServerMsg::DeliverBatch(ds) = ServerMsg::from_frame(&read).unwrap() else {
            panic!("expected batch");
        };
        assert_eq!(ds.len(), 4);
        for pair in ds.windows(2) {
            assert!(
                Bytes::same_buffer(&pair[0].body, &pair[1].body),
                "all bodies of a read batch must be views of the receive buffer"
            );
        }
    }

    #[test]
    fn batch_decode_interns_repeated_names() {
        // A drained batch from one queue repeats the same exchange and
        // routing key in every envelope — the decoder must share one
        // Arc<str> per distinct name across the batch, not allocate per
        // delivery.
        let batch = ServerMsg::DeliverBatch(
            (0..4)
                .map(|i| Delivery {
                    consumer_tag: "ct".into(),
                    delivery_tag: i,
                    redelivered: false,
                    exchange: "events".into(),
                    routing_key: "proc.42.done".into(),
                    body: Bytes::encode(&Value::I64(i as i64)),
                    props: MessageProps::default().into(),
                    offset: None,
                })
                .collect(),
        );
        let mut buf = Vec::new();
        write_frame(&mut buf, &batch.to_frame()).unwrap();
        let read = read_frame(&mut Cursor::new(&buf)).unwrap();
        let ServerMsg::DeliverBatch(ds) = ServerMsg::from_frame(&read).unwrap() else {
            panic!("expected batch");
        };
        for pair in ds.windows(2) {
            assert!(
                Arc::ptr_eq(&pair[0].exchange, &pair[1].exchange),
                "repeated exchange names must share one allocation"
            );
            assert!(
                Arc::ptr_eq(&pair[0].routing_key, &pair[1].routing_key),
                "repeated routing keys must share one allocation"
            );
        }
    }

    #[test]
    fn encoded_props_cache_is_reused() {
        let props: EncodedProps = MessageProps {
            headers: [("k".to_string(), Value::str("v"))].into_iter().collect(),
            ..Default::default()
        }
        .into();
        let a = props.clone();
        let b = props.clone();
        assert!(Bytes::same_buffer(a.bytes(), b.bytes()), "clones share the single encode");
        assert_eq!(a.bytes().decode().unwrap(), props.props().to_value());
    }

    #[test]
    fn default_props_encode_empty() {
        let v = MessageProps::default().to_value();
        assert_eq!(v, Value::Map(Default::default()));
        assert_eq!(MessageProps::from_value(&v).unwrap(), MessageProps::default());
        assert_eq!(EncodedProps::default().bytes().decode().unwrap(), v);
    }

    #[test]
    fn priority_clamped_to_nine() {
        let v = Value::map([("priority", Value::I64(99))]);
        assert_eq!(MessageProps::from_value(&v).unwrap().priority, 9);
    }

    #[test]
    fn unknown_op_rejected() {
        let frame =
            Frame::data(&Value::map([("op", Value::str("evil")), ("req_id", Value::I64(1))]));
        assert!(ClientRequest::from_frame(&frame).is_err());
    }

    #[test]
    fn truncated_publish_sections_rejected() {
        let r = ClientRequest::Publish {
            exchange: "".into(),
            routing_key: "q".into(),
            body: Bytes::encode(&Value::str("hello")),
            props: MessageProps::default().into(),
            mandatory: false,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &r.to_frame(1)).unwrap();
        // Chop the tail of the frame payload (but keep the header's length
        // intact by rewriting it) so the declared body_len overruns.
        let total = buf.len();
        let cut = total - 3;
        let mut shorter = buf[..cut].to_vec();
        let payload_len = (cut - 5) as u32;
        shorter[..4].copy_from_slice(&payload_len.to_le_bytes());
        let read = read_frame(&mut Cursor::new(&shorter)).unwrap();
        assert!(ClientRequest::from_frame(&read).is_err());
    }
}
