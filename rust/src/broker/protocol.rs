//! Client ⇄ broker protocol messages and their [`Value`] encodings.
//!
//! Every request carries a client-chosen `req_id`; the broker answers with
//! `Ok {req_id, ..}` or `Err {req_id, ..}`. Deliveries are unsolicited
//! (push) messages tied to a consumer tag, exactly like AMQP's
//! `basic.deliver`.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::wire::Value;

/// Message properties (the AMQP `basic.properties` subset kiwiPy uses).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MessageProps {
    /// Correlates an RPC reply with its request.
    pub correlation_id: Option<String>,
    /// Queue the reply should be published to.
    pub reply_to: Option<String>,
    /// Per-message TTL in milliseconds.
    pub expiration_ms: Option<u64>,
    /// 0–9, higher is delivered first (within a queue).
    pub priority: u8,
    /// Persist to the WAL when the queue is durable.
    pub persistent: bool,
    /// Free-form application headers.
    pub headers: BTreeMap<String, Value>,
}

impl MessageProps {
    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        if let Some(c) = &self.correlation_id {
            m.insert("correlation_id".into(), Value::str(c));
        }
        if let Some(r) = &self.reply_to {
            m.insert("reply_to".into(), Value::str(r));
        }
        if let Some(e) = self.expiration_ms {
            m.insert("expiration_ms".into(), Value::from(e));
        }
        if self.priority != 0 {
            m.insert("priority".into(), Value::I64(self.priority as i64));
        }
        if self.persistent {
            m.insert("persistent".into(), Value::Bool(true));
        }
        if !self.headers.is_empty() {
            m.insert("headers".into(), Value::Map(self.headers.clone()));
        }
        Value::Map(m)
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let mut p = MessageProps::default();
        if let Some(c) = v.get_opt("correlation_id") {
            p.correlation_id = Some(c.as_str()?.to_string());
        }
        if let Some(r) = v.get_opt("reply_to") {
            p.reply_to = Some(r.as_str()?.to_string());
        }
        if let Some(e) = v.get_opt("expiration_ms") {
            p.expiration_ms = Some(e.as_u64()?);
        }
        if let Some(pr) = v.get_opt("priority") {
            p.priority = pr.as_u64()?.min(9) as u8;
        }
        if let Some(pe) = v.get_opt("persistent") {
            p.persistent = pe.as_bool()?;
        }
        if let Some(h) = v.get_opt("headers") {
            p.headers = h.as_map()?.clone();
        }
        Ok(p)
    }
}

/// Exchange types (mirrors AMQP).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeKind {
    /// Route on exact `routing_key` match.
    Direct,
    /// Route to every bound queue.
    Fanout,
    /// Route on dotted-pattern match with `*` (one word) / `#` (≥0 words).
    Topic,
}

impl ExchangeKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ExchangeKind::Direct => "direct",
            ExchangeKind::Fanout => "fanout",
            ExchangeKind::Topic => "topic",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "direct" => Ok(ExchangeKind::Direct),
            "fanout" => Ok(ExchangeKind::Fanout),
            "topic" => Ok(ExchangeKind::Topic),
            other => Err(Error::Wire(format!("unknown exchange kind '{other}'"))),
        }
    }
}

/// Options for queue declaration.
#[derive(Clone, Debug, PartialEq)]
pub struct QueueOptions {
    /// Survive broker restart (messages go through the WAL).
    pub durable: bool,
    /// Only the declaring connection may consume; deleted when it closes.
    pub exclusive: bool,
    /// Delete when the last consumer cancels.
    pub auto_delete: bool,
    /// Default TTL applied to messages without their own expiration.
    pub default_ttl_ms: Option<u64>,
    /// Maximum queue length; publishes beyond it drop the *oldest* ready
    /// message (RabbitMQ default-on-overflow behaviour).
    pub max_length: Option<usize>,
}

impl Default for QueueOptions {
    fn default() -> Self {
        QueueOptions {
            durable: false,
            exclusive: false,
            auto_delete: false,
            default_ttl_ms: None,
            max_length: None,
        }
    }
}

impl QueueOptions {
    pub fn durable() -> Self {
        QueueOptions { durable: true, ..Default::default() }
    }

    pub fn to_value(&self) -> Value {
        Value::map([
            ("durable", Value::Bool(self.durable)),
            ("exclusive", Value::Bool(self.exclusive)),
            ("auto_delete", Value::Bool(self.auto_delete)),
            ("default_ttl_ms", self.default_ttl_ms.into()),
            ("max_length", self.max_length.map(|n| n as u64).into()),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(QueueOptions {
            durable: v.get_opt("durable").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
            exclusive: v.get_opt("exclusive").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
            auto_delete: v
                .get_opt("auto_delete")
                .map(|b| b.as_bool())
                .transpose()?
                .unwrap_or(false),
            default_ttl_ms: v.get_opt("default_ttl_ms").map(|x| x.as_u64()).transpose()?,
            max_length: v
                .get_opt("max_length")
                .map(|x| x.as_u64().map(|n| n as usize))
                .transpose()?,
        })
    }
}

/// Requests a client may send.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientRequest {
    /// First frame on a connection; sets identity and heartbeat interval.
    Hello { client_id: String, heartbeat_ms: u64 },
    QueueDeclare { queue: String, options: QueueOptions },
    QueueDelete { queue: String },
    QueuePurge { queue: String },
    ExchangeDeclare { exchange: String, kind: ExchangeKind },
    Bind { exchange: String, queue: String, routing_key: String },
    Unbind { exchange: String, queue: String, routing_key: String },
    Publish {
        /// Empty string = default exchange (routes directly to the queue
        /// named by `routing_key`), as in AMQP.
        exchange: String,
        routing_key: String,
        body: Arc<Value>,
        props: MessageProps,
        /// When true and the message routes to zero queues, the broker
        /// answers with an `unroutable` error instead of dropping it.
        mandatory: bool,
    },
    Consume { queue: String, consumer_tag: String, prefetch: u32 },
    Cancel { consumer_tag: String },
    Ack { delivery_tag: u64 },
    /// Acknowledge many deliveries in one frame (the client-side ack
    /// pipeline coalesces acks issued while a delivery batch is being
    /// dispatched). Each tag is acked independently and idempotently.
    AckMulti { delivery_tags: Vec<u64> },
    Nack { delivery_tag: u64, requeue: bool },
    /// Broker status snapshot (queue depths, counters).
    Status,
    Close,
}

/// An unsolicited message delivery (broker → consumer).
#[derive(Clone, Debug, PartialEq)]
pub struct Delivery {
    pub consumer_tag: String,
    pub delivery_tag: u64,
    pub redelivered: bool,
    pub exchange: String,
    pub routing_key: String,
    pub body: Arc<Value>,
    pub props: MessageProps,
}

/// Messages the broker sends to a client.
#[derive(Clone, Debug, PartialEq)]
pub enum ServerMsg {
    Ok { req_id: u64, reply: Value },
    Err { req_id: u64, code: String, message: String },
    Deliver(Delivery),
    /// Several deliveries coalesced into one frame by the batched
    /// dispatcher — one channel-send / one syscall for the whole batch.
    /// Clients dispatch the contained deliveries in order.
    DeliverBatch(Vec<Delivery>),
    /// Consumer cancelled server-side (queue deleted / exclusivity).
    CancelConsumer { consumer_tag: String },
}

fn req(op: &str, req_id: u64, fields: Vec<(&str, Value)>) -> Value {
    let mut m: BTreeMap<String, Value> =
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    m.insert("op".into(), Value::str(op));
    m.insert("req_id".into(), Value::from(req_id));
    Value::Map(m)
}

impl ClientRequest {
    /// Encode with a request id.
    pub fn to_value(&self, req_id: u64) -> Value {
        match self {
            ClientRequest::Hello { client_id, heartbeat_ms } => req(
                "hello",
                req_id,
                vec![
                    ("client_id", Value::str(client_id)),
                    ("heartbeat_ms", Value::from(*heartbeat_ms)),
                ],
            ),
            ClientRequest::QueueDeclare { queue, options } => req(
                "queue_declare",
                req_id,
                vec![("queue", Value::str(queue)), ("options", options.to_value())],
            ),
            ClientRequest::QueueDelete { queue } => {
                req("queue_delete", req_id, vec![("queue", Value::str(queue))])
            }
            ClientRequest::QueuePurge { queue } => {
                req("queue_purge", req_id, vec![("queue", Value::str(queue))])
            }
            ClientRequest::ExchangeDeclare { exchange, kind } => req(
                "exchange_declare",
                req_id,
                vec![("exchange", Value::str(exchange)), ("kind", Value::str(kind.as_str()))],
            ),
            ClientRequest::Bind { exchange, queue, routing_key } => req(
                "bind",
                req_id,
                vec![
                    ("exchange", Value::str(exchange)),
                    ("queue", Value::str(queue)),
                    ("routing_key", Value::str(routing_key)),
                ],
            ),
            ClientRequest::Unbind { exchange, queue, routing_key } => req(
                "unbind",
                req_id,
                vec![
                    ("exchange", Value::str(exchange)),
                    ("queue", Value::str(queue)),
                    ("routing_key", Value::str(routing_key)),
                ],
            ),
            ClientRequest::Publish { exchange, routing_key, body, props, mandatory } => req(
                "publish",
                req_id,
                vec![
                    ("exchange", Value::str(exchange)),
                    ("routing_key", Value::str(routing_key)),
                    ("body", (**body).clone()),
                    ("props", props.to_value()),
                    ("mandatory", Value::Bool(*mandatory)),
                ],
            ),
            ClientRequest::Consume { queue, consumer_tag, prefetch } => req(
                "consume",
                req_id,
                vec![
                    ("queue", Value::str(queue)),
                    ("consumer_tag", Value::str(consumer_tag)),
                    ("prefetch", Value::from(*prefetch as u64)),
                ],
            ),
            ClientRequest::Cancel { consumer_tag } => {
                req("cancel", req_id, vec![("consumer_tag", Value::str(consumer_tag))])
            }
            ClientRequest::Ack { delivery_tag } => {
                req("ack", req_id, vec![("delivery_tag", Value::from(*delivery_tag))])
            }
            ClientRequest::AckMulti { delivery_tags } => req(
                "ack_multi",
                req_id,
                vec![(
                    "delivery_tags",
                    Value::List(delivery_tags.iter().map(|t| Value::from(*t)).collect()),
                )],
            ),
            ClientRequest::Nack { delivery_tag, requeue } => req(
                "nack",
                req_id,
                vec![
                    ("delivery_tag", Value::from(*delivery_tag)),
                    ("requeue", Value::Bool(*requeue)),
                ],
            ),
            ClientRequest::Status => req("status", req_id, vec![]),
            ClientRequest::Close => req("close", req_id, vec![]),
        }
    }

    /// Decode; returns `(request, req_id)`.
    pub fn from_value(v: &Value) -> Result<(Self, u64)> {
        let req_id = v.get_u64("req_id")?;
        let op = v.get_str("op")?;
        let r = match op {
            "hello" => ClientRequest::Hello {
                client_id: v.get_str("client_id")?.to_string(),
                heartbeat_ms: v.get_u64("heartbeat_ms")?,
            },
            "queue_declare" => ClientRequest::QueueDeclare {
                queue: v.get_str("queue")?.to_string(),
                options: QueueOptions::from_value(v.get("options")?)?,
            },
            "queue_delete" => ClientRequest::QueueDelete { queue: v.get_str("queue")?.to_string() },
            "queue_purge" => ClientRequest::QueuePurge { queue: v.get_str("queue")?.to_string() },
            "exchange_declare" => ClientRequest::ExchangeDeclare {
                exchange: v.get_str("exchange")?.to_string(),
                kind: ExchangeKind::parse(v.get_str("kind")?)?,
            },
            "bind" => ClientRequest::Bind {
                exchange: v.get_str("exchange")?.to_string(),
                queue: v.get_str("queue")?.to_string(),
                routing_key: v.get_str("routing_key")?.to_string(),
            },
            "unbind" => ClientRequest::Unbind {
                exchange: v.get_str("exchange")?.to_string(),
                queue: v.get_str("queue")?.to_string(),
                routing_key: v.get_str("routing_key")?.to_string(),
            },
            "publish" => ClientRequest::Publish {
                exchange: v.get_str("exchange")?.to_string(),
                routing_key: v.get_str("routing_key")?.to_string(),
                body: Arc::new(v.get("body")?.clone()),
                props: MessageProps::from_value(v.get("props")?)?,
                mandatory: v.get_bool("mandatory")?,
            },
            "consume" => ClientRequest::Consume {
                queue: v.get_str("queue")?.to_string(),
                consumer_tag: v.get_str("consumer_tag")?.to_string(),
                prefetch: v.get_u64("prefetch")? as u32,
            },
            "cancel" => {
                ClientRequest::Cancel { consumer_tag: v.get_str("consumer_tag")?.to_string() }
            }
            "ack" => ClientRequest::Ack { delivery_tag: v.get_u64("delivery_tag")? },
            "ack_multi" => ClientRequest::AckMulti {
                delivery_tags: v
                    .get("delivery_tags")?
                    .as_list()?
                    .iter()
                    .map(|t| t.as_u64())
                    .collect::<Result<Vec<u64>>>()?,
            },
            "nack" => ClientRequest::Nack {
                delivery_tag: v.get_u64("delivery_tag")?,
                requeue: v.get_bool("requeue")?,
            },
            "status" => ClientRequest::Status,
            "close" => ClientRequest::Close,
            other => return Err(Error::Wire(format!("unknown op '{other}'"))),
        };
        Ok((r, req_id))
    }
}

impl Delivery {
    pub fn to_value(&self) -> Value {
        Value::map([
            ("kind", Value::str("deliver")),
            ("consumer_tag", Value::str(&self.consumer_tag)),
            ("delivery_tag", Value::from(self.delivery_tag)),
            ("redelivered", Value::Bool(self.redelivered)),
            ("exchange", Value::str(&self.exchange)),
            ("routing_key", Value::str(&self.routing_key)),
            ("body", (*self.body).clone()),
            ("props", self.props.to_value()),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(Delivery {
            consumer_tag: v.get_str("consumer_tag")?.to_string(),
            delivery_tag: v.get_u64("delivery_tag")?,
            redelivered: v.get_bool("redelivered")?,
            exchange: v.get_str("exchange")?.to_string(),
            routing_key: v.get_str("routing_key")?.to_string(),
            body: Arc::new(v.get("body")?.clone()),
            props: MessageProps::from_value(v.get("props")?)?,
        })
    }
}

impl ServerMsg {
    pub fn to_value(&self) -> Value {
        match self {
            ServerMsg::Ok { req_id, reply } => Value::map([
                ("kind", Value::str("ok")),
                ("req_id", Value::from(*req_id)),
                ("reply", reply.clone()),
            ]),
            ServerMsg::Err { req_id, code, message } => Value::map([
                ("kind", Value::str("err")),
                ("req_id", Value::from(*req_id)),
                ("code", Value::str(code)),
                ("message", Value::str(message)),
            ]),
            ServerMsg::Deliver(d) => d.to_value(),
            ServerMsg::DeliverBatch(ds) => Value::map([
                ("kind", Value::str("deliver_batch")),
                ("deliveries", Value::List(ds.iter().map(Delivery::to_value).collect())),
            ]),
            ServerMsg::CancelConsumer { consumer_tag } => Value::map([
                ("kind", Value::str("cancel_consumer")),
                ("consumer_tag", Value::str(consumer_tag)),
            ]),
        }
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        match v.get_str("kind")? {
            "ok" => Ok(ServerMsg::Ok {
                req_id: v.get_u64("req_id")?,
                reply: v.get("reply")?.clone(),
            }),
            "err" => Ok(ServerMsg::Err {
                req_id: v.get_u64("req_id")?,
                code: v.get_str("code")?.to_string(),
                message: v.get_str("message")?.to_string(),
            }),
            "deliver" => Ok(ServerMsg::Deliver(Delivery::from_value(v)?)),
            "deliver_batch" => Ok(ServerMsg::DeliverBatch(
                v.get("deliveries")?
                    .as_list()?
                    .iter()
                    .map(Delivery::from_value)
                    .collect::<Result<Vec<Delivery>>>()?,
            )),
            "cancel_consumer" => Ok(ServerMsg::CancelConsumer {
                consumer_tag: v.get_str("consumer_tag")?.to_string(),
            }),
            other => Err(Error::Wire(format!("unknown server msg kind '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: ClientRequest) {
        let v = r.to_value(42);
        let (back, id) = ClientRequest::from_value(&v).unwrap();
        assert_eq!(id, 42);
        assert_eq!(back, r);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(ClientRequest::Hello { client_id: "w1".into(), heartbeat_ms: 500 });
        roundtrip_req(ClientRequest::QueueDeclare {
            queue: "tasks".into(),
            options: QueueOptions {
                durable: true,
                exclusive: false,
                auto_delete: true,
                default_ttl_ms: Some(1000),
                max_length: Some(100),
            },
        });
        roundtrip_req(ClientRequest::ExchangeDeclare {
            exchange: "bc".into(),
            kind: ExchangeKind::Fanout,
        });
        roundtrip_req(ClientRequest::Bind {
            exchange: "rpc".into(),
            queue: "q".into(),
            routing_key: "proc.123".into(),
        });
        roundtrip_req(ClientRequest::Publish {
            exchange: "".into(),
            routing_key: "tasks".into(),
            body: Arc::new(Value::map([("x", Value::I64(1))])),
            props: MessageProps {
                correlation_id: Some("c1".into()),
                reply_to: Some("replies".into()),
                expiration_ms: Some(5000),
                priority: 7,
                persistent: true,
                headers: [("sender".to_string(), Value::str("me"))].into_iter().collect(),
            },
            mandatory: true,
        });
        roundtrip_req(ClientRequest::Consume {
            queue: "tasks".into(),
            consumer_tag: "ct-1".into(),
            prefetch: 1,
        });
        roundtrip_req(ClientRequest::Ack { delivery_tag: 99 });
        roundtrip_req(ClientRequest::AckMulti { delivery_tags: vec![3, 5, 8, 13] });
        roundtrip_req(ClientRequest::AckMulti { delivery_tags: vec![] });
        roundtrip_req(ClientRequest::Nack { delivery_tag: 100, requeue: true });
        roundtrip_req(ClientRequest::Status);
        roundtrip_req(ClientRequest::Close);
    }

    #[test]
    fn server_msgs_roundtrip() {
        for m in [
            ServerMsg::Ok { req_id: 1, reply: Value::Null },
            ServerMsg::Err { req_id: 2, code: "broker".into(), message: "no such queue".into() },
            ServerMsg::Deliver(Delivery {
                consumer_tag: "ct".into(),
                delivery_tag: 7,
                redelivered: true,
                exchange: "".into(),
                routing_key: "tasks".into(),
                body: Arc::new(Value::str("payload")),
                props: MessageProps::default(),
            }),
            ServerMsg::DeliverBatch(
                (0..3)
                    .map(|i| Delivery {
                        consumer_tag: "ct".into(),
                        delivery_tag: i,
                        redelivered: false,
                        exchange: "".into(),
                        routing_key: "tasks".into(),
                        body: Arc::new(Value::I64(i as i64)),
                        props: MessageProps::default(),
                    })
                    .collect(),
            ),
            ServerMsg::CancelConsumer { consumer_tag: "ct".into() },
        ] {
            let v = m.to_value();
            assert_eq!(ServerMsg::from_value(&v).unwrap(), m);
        }
    }

    #[test]
    fn default_props_encode_empty() {
        let v = MessageProps::default().to_value();
        assert_eq!(v, Value::Map(Default::default()));
        assert_eq!(MessageProps::from_value(&v).unwrap(), MessageProps::default());
    }

    #[test]
    fn priority_clamped_to_nine() {
        let v = Value::map([("priority", Value::I64(99))]);
        assert_eq!(MessageProps::from_value(&v).unwrap().priority, 9);
    }

    #[test]
    fn unknown_op_rejected() {
        let v = Value::map([("op", Value::str("evil")), ("req_id", Value::I64(1))]);
        assert!(ClientRequest::from_value(&v).is_err());
    }
}
