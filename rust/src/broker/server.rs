//! TCP front-end for the broker, in one of two modes:
//!
//! * [`NetMode::Reactor`] (default where supported): a single epoll event
//!   loop (`broker::reactor`) serves every connection — O(1) threads for
//!   the whole front-end, per-connection outbox backpressure.
//! * [`NetMode::Threads`] (`KIWI_NET=threads`, and the automatic fallback
//!   on targets without the reactor): the historical pair of blocking
//!   reader/writer threads per client.

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::broker::core::BrokerHandle;
use crate::broker::heartbeat::HeartbeatMonitor;
use crate::broker::reactor::{self, ReactorHandle, ReactorOptions};
use crate::broker::session::serve_link;
use crate::error::Result;
use crate::transport::link::TcpLink;
use crate::transport::Link;

/// Which networking front-end serves TCP clients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetMode {
    /// Single epoll reactor thread (default where supported).
    Reactor,
    /// Blocking reader + writer thread pair per connection.
    Threads,
}

/// Front-end selection plus reactor tuning, resolved from the
/// environment by [`NetOptions::from_env`] or built explicitly.
#[derive(Clone, Copy, Debug)]
pub struct NetOptions {
    pub mode: NetMode,
    pub reactor: ReactorOptions,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            mode: if reactor::supported() { NetMode::Reactor } else { NetMode::Threads },
            reactor: ReactorOptions::default(),
        }
    }
}

impl NetOptions {
    /// Resolve from `KIWI_NET` / `KIWI_EVENT_BATCH` / `KIWI_OUTBOX_CAP`.
    /// Unknown or unsupported values fall back to the default mode.
    pub fn from_env() -> NetOptions {
        let mut opts = NetOptions::default();
        if let Ok(v) = std::env::var("KIWI_NET") {
            match v.as_str() {
                "threads" => opts.mode = NetMode::Threads,
                "reactor" if reactor::supported() => opts.mode = NetMode::Reactor,
                "reactor" => {
                    log::warn!("KIWI_NET=reactor unsupported on this target; using threads");
                    opts.mode = NetMode::Threads;
                }
                other => log::warn!("ignoring unknown KIWI_NET={other}"),
            }
        }
        if let Ok(v) = std::env::var("KIWI_EVENT_BATCH") {
            if let Ok(n) = v.parse::<usize>() {
                opts.reactor.event_batch = n.max(1);
            }
        }
        if let Ok(v) = std::env::var("KIWI_OUTBOX_CAP") {
            if let Ok(n) = v.parse::<usize>() {
                opts.reactor.outbox_cap = n.max(1);
            }
        }
        opts
    }
}

/// The running front-end's threads and teardown state.
enum FrontEnd {
    Threads {
        acceptor: Option<JoinHandle<()>>,
        /// Live session links, so shutdown can sever clients that have
        /// not disconnected themselves (sessions exit on a closed link).
        links: Arc<std::sync::Mutex<Vec<std::sync::Weak<dyn Link>>>>,
    },
    Reactor { handle: Option<ReactorHandle> },
}

/// A running broker server: network front-end + heartbeat monitor.
pub struct BrokerServer {
    broker: BrokerHandle,
    addr: SocketAddr,
    mode: NetMode,
    stop: Arc<AtomicBool>,
    front: FrontEnd,
    _monitor: HeartbeatMonitor,
}

impl BrokerServer {
    /// Bind and start serving with environment-resolved networking
    /// options. Use port 0 for an ephemeral port (tests).
    pub fn start(broker: BrokerHandle, bind: &str) -> Result<Self> {
        Self::start_with(broker, bind, NetOptions::from_env())
    }

    /// Bind and start serving with explicit networking options.
    pub fn start_with(broker: BrokerHandle, bind: &str, opts: NetOptions) -> Result<Self> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let front = match opts.mode {
            NetMode::Reactor => {
                let handle =
                    reactor::spawn(broker.clone(), listener, opts.reactor, Arc::clone(&stop))?;
                FrontEnd::Reactor { handle: Some(handle) }
            }
            NetMode::Threads => FrontEnd::Threads {
                acceptor: None,
                links: Arc::new(std::sync::Mutex::new(Vec::new())),
            },
        };
        let mut server = BrokerServer {
            broker: broker.clone(),
            addr,
            mode: opts.mode,
            stop,
            front,
            _monitor: HeartbeatMonitor::spawn(broker, Duration::from_millis(100)),
        };
        if opts.mode == NetMode::Threads {
            server.start_threads_acceptor(listener)?;
        }
        Ok(server)
    }

    fn start_threads_acceptor(&mut self, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        let stop2 = Arc::clone(&self.stop);
        let broker2 = self.broker.clone();
        let FrontEnd::Threads { acceptor, links } = &mut self.front else { unreachable!() };
        let links2 = Arc::clone(links);
        let handle = std::thread::Builder::new()
            .name("kiwi-broker-acceptor".into())
            .spawn(move || {
                let mut sessions: Vec<JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log::info!("broker: accepted {peer}");
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            match TcpLink::new(stream) {
                                Ok(link) => {
                                    let b = broker2.clone();
                                    let link: Arc<dyn Link> = Arc::new(link);
                                    {
                                        let mut links = links2.lock().unwrap();
                                        links.retain(|w| w.upgrade().is_some());
                                        links.push(Arc::downgrade(&link));
                                    }
                                    sessions.retain(|h| !h.is_finished());
                                    sessions.push(
                                        std::thread::Builder::new()
                                            .name(format!("kiwi-session-{peer}"))
                                            .spawn(move || serve_link(b, link))
                                            .expect("spawn session"),
                                    );
                                }
                                Err(e) => log::warn!("broker: link setup failed: {e}"),
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            // Kernel-reported readiness instead of a fixed
                            // sleep: accepts land immediately while the
                            // stop flag is still polled on a bound.
                            reactor::listener_wait_readable(
                                &listener,
                                Duration::from_millis(100),
                            );
                        }
                        Err(e) => {
                            log::error!("broker: accept error: {e}");
                            break;
                        }
                    }
                }
                // Sever any client that has not hung up; sessions then see
                // a closed link and exit, making this join prompt.
                for weak in links2.lock().unwrap().drain(..) {
                    if let Some(link) = weak.upgrade() {
                        link.close();
                    }
                }
                for h in sessions {
                    h.join().ok();
                }
            })
            .expect("spawn acceptor");
        *acceptor = Some(handle);
        Ok(())
    }

    /// Address the server is listening on (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Which front-end is serving clients.
    pub fn net_mode(&self) -> NetMode {
        self.mode
    }

    /// The underlying broker (for embedding / inspection).
    pub fn broker(&self) -> &BrokerHandle {
        &self.broker
    }

    /// Graceful shutdown: sync the WAL, stop accepting, drop sessions.
    pub fn shutdown(mut self) {
        self.stop_internal();
    }

    fn stop_internal(&mut self) {
        self.broker.sync().ok();
        self.stop.store(true, Ordering::Release);
        match &mut self.front {
            FrontEnd::Threads { acceptor, links } => {
                // Sever clients immediately (the acceptor also does this
                // on its way out; doing it here makes shutdown prompt
                // even while the acceptor waits for readiness).
                for weak in links.lock().unwrap().drain(..) {
                    if let Some(link) = weak.upgrade() {
                        link.close();
                    }
                }
                if let Some(h) = acceptor.take() {
                    h.join().ok();
                }
            }
            FrontEnd::Reactor { handle } => {
                if let Some(mut h) = handle.take() {
                    h.wake();
                    h.join();
                }
            }
        }
    }

    fn is_running(&self) -> bool {
        match &self.front {
            FrontEnd::Threads { acceptor, .. } => acceptor.is_some(),
            FrontEnd::Reactor { handle } => handle.is_some(),
        }
    }
}

impl Drop for BrokerServer {
    fn drop(&mut self) {
        if self.is_running() {
            self.stop_internal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::{ClientRequest, QueueOptions, ServerMsg};
    use crate::transport::connect_tcp;
    use crate::wire::{Frame, FrameType, Value};

    fn start_default(broker: BrokerHandle) -> BrokerServer {
        // Tests pin the default mode explicitly so a KIWI_NET set in the
        // environment cannot change what this file asserts.
        BrokerServer::start_with(broker, "127.0.0.1:0", NetOptions::default()).unwrap()
    }

    #[test]
    fn server_accepts_and_serves_tcp_clients() {
        let server = start_default(BrokerHandle::new());
        if reactor::supported() {
            assert_eq!(server.net_mode(), NetMode::Reactor);
        }
        let addr = server.addr();
        let link = connect_tcp(addr).unwrap();
        link.send(
            &ClientRequest::QueueDeclare { queue: "q".into(), options: QueueOptions::default() }
                .to_frame(1),
        )
        .unwrap();
        let f = loop {
            let f = link.recv_timeout(Duration::from_secs(2)).unwrap();
            if f.frame_type == FrameType::Data {
                break f;
            }
        };
        match ServerMsg::from_frame(&f).unwrap() {
            ServerMsg::Ok { req_id: 1, reply } => {
                assert_eq!(reply.get_str("queue").unwrap(), "q");
            }
            other => panic!("unexpected: {other:?}"),
        }
        link.send(&Frame::goodbye("test done")).unwrap();
        server.shutdown();
    }

    #[test]
    fn abrupt_tcp_disconnect_requeues() {
        let server = start_default(BrokerHandle::new());
        let broker = server.broker().clone();
        let addr = server.addr();
        {
            let link = connect_tcp(addr).unwrap();
            let send = |req: &ClientRequest, id: u64| link.send(&req.to_frame(id)).unwrap();
            send(
                &ClientRequest::QueueDeclare {
                    queue: "tasks".into(),
                    options: QueueOptions::default(),
                },
                1,
            );
            send(
                &ClientRequest::Publish {
                    exchange: "".into(),
                    routing_key: "tasks".into(),
                    body: crate::wire::Bytes::encode(&Value::str("work")),
                    props: Default::default(),
                    mandatory: true,
                },
                2,
            );
            send(
                &ClientRequest::Consume {
                    queue: "tasks".into(),
                    consumer_tag: "doomed".into(),
                    prefetch: 0,
                },
                3,
            );
            // Wait for the delivery to be in flight.
            let deadline = std::time::Instant::now() + Duration::from_secs(2);
            while broker.queue_unacked("tasks") != Some(1) {
                assert!(std::time::Instant::now() < deadline);
                std::thread::sleep(Duration::from_millis(5));
            }
            // Drop the socket without acking — simulated crash.
            link.close();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while broker.queue_depth("tasks") != Some(1) {
            assert!(std::time::Instant::now() < deadline, "message was not requeued");
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
    }

    /// The threads front-end stays available behind `KIWI_NET=threads`.
    #[test]
    fn threads_escape_hatch_serves_clients() {
        let opts = NetOptions { mode: NetMode::Threads, ..NetOptions::default() };
        let server =
            BrokerServer::start_with(BrokerHandle::new(), "127.0.0.1:0", opts).unwrap();
        assert_eq!(server.net_mode(), NetMode::Threads);
        let link = connect_tcp(server.addr()).unwrap();
        link.send(
            &ClientRequest::QueueDeclare { queue: "t".into(), options: QueueOptions::default() }
                .to_frame(7),
        )
        .unwrap();
        let f = loop {
            let f = link.recv_timeout(Duration::from_secs(2)).unwrap();
            if f.frame_type == FrameType::Data {
                break f;
            }
        };
        assert!(matches!(ServerMsg::from_frame(&f).unwrap(), ServerMsg::Ok { req_id: 7, .. }));
        link.send(&Frame::goodbye("done")).unwrap();
        server.shutdown();
    }
}
