//! TCP front-end: accepts connections and runs a [`session`] per client.

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::broker::core::BrokerHandle;
use crate::broker::heartbeat::HeartbeatMonitor;
use crate::broker::session::serve_link;
use crate::error::Result;
use crate::transport::link::TcpLink;
use crate::transport::Link;

/// A running broker server: TCP acceptor + heartbeat monitor.
pub struct BrokerServer {
    broker: BrokerHandle,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    /// Live session links, so shutdown can sever clients that have not
    /// disconnected themselves (sessions exit on a closed link).
    links: Arc<std::sync::Mutex<Vec<std::sync::Weak<dyn Link>>>>,
    _monitor: HeartbeatMonitor,
}

impl BrokerServer {
    /// Bind and start serving. Use port 0 for an ephemeral port (tests).
    pub fn start(broker: BrokerHandle, bind: &str) -> Result<Self> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let broker2 = broker.clone();
        let links: Arc<std::sync::Mutex<Vec<std::sync::Weak<dyn Link>>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let links2 = Arc::clone(&links);
        let acceptor = std::thread::Builder::new()
            .name("kiwi-broker-acceptor".into())
            .spawn(move || {
                let mut sessions: Vec<JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log::info!("broker: accepted {peer}");
                            stream.set_nonblocking(false).ok();
                            match TcpLink::new(stream) {
                                Ok(link) => {
                                    let b = broker2.clone();
                                    let link: Arc<dyn Link> = Arc::new(link);
                                    {
                                        let mut links = links2.lock().unwrap();
                                        links.retain(|w| w.upgrade().is_some());
                                        links.push(Arc::downgrade(&link));
                                    }
                                    sessions.retain(|h| !h.is_finished());
                                    sessions.push(
                                        std::thread::Builder::new()
                                            .name(format!("kiwi-session-{peer}"))
                                            .spawn(move || serve_link(b, link))
                                            .expect("spawn session"),
                                    );
                                }
                                Err(e) => log::warn!("broker: link setup failed: {e}"),
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(e) => {
                            log::error!("broker: accept error: {e}");
                            break;
                        }
                    }
                }
                // Sever any client that has not hung up; sessions then see
                // a closed link and exit, making this join prompt.
                for weak in links2.lock().unwrap().drain(..) {
                    if let Some(link) = weak.upgrade() {
                        link.close();
                    }
                }
                for h in sessions {
                    h.join().ok();
                }
            })
            .expect("spawn acceptor");
        let monitor = HeartbeatMonitor::spawn(broker.clone(), Duration::from_millis(100));
        Ok(BrokerServer { broker, addr, stop, acceptor: Some(acceptor), links, _monitor: monitor })
    }

    /// Address the server is listening on (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The underlying broker (for embedding / inspection).
    pub fn broker(&self) -> &BrokerHandle {
        &self.broker
    }

    /// Graceful shutdown: sync the WAL, stop accepting, drop sessions.
    pub fn shutdown(mut self) {
        self.stop_internal();
    }

    fn stop_internal(&mut self) {
        self.broker.sync().ok();
        self.stop.store(true, Ordering::Relaxed);
        // Sever clients immediately (the acceptor also does this on its
        // way out; doing it here makes shutdown prompt even while the
        // acceptor sleeps between polls).
        for weak in self.links.lock().unwrap().drain(..) {
            if let Some(link) = weak.upgrade() {
                link.close();
            }
        }
        if let Some(h) = self.acceptor.take() {
            h.join().ok();
        }
    }
}

impl Drop for BrokerServer {
    fn drop(&mut self) {
        if self.acceptor.is_some() {
            self.stop_internal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::{ClientRequest, QueueOptions, ServerMsg};
    use crate::transport::connect_tcp;
    use crate::wire::{Frame, FrameType, Value};

    #[test]
    fn server_accepts_and_serves_tcp_clients() {
        let server = BrokerServer::start(BrokerHandle::new(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let link = connect_tcp(addr).unwrap();
        link.send(
            &ClientRequest::QueueDeclare { queue: "q".into(), options: QueueOptions::default() }
                .to_frame(1),
        )
        .unwrap();
        let f = loop {
            let f = link.recv_timeout(Duration::from_secs(2)).unwrap();
            if f.frame_type == FrameType::Data {
                break f;
            }
        };
        match ServerMsg::from_frame(&f).unwrap() {
            ServerMsg::Ok { req_id: 1, reply } => {
                assert_eq!(reply.get_str("queue").unwrap(), "q");
            }
            other => panic!("unexpected: {other:?}"),
        }
        link.send(&Frame::goodbye("test done")).unwrap();
        server.shutdown();
    }

    #[test]
    fn abrupt_tcp_disconnect_requeues() {
        let server = BrokerServer::start(BrokerHandle::new(), "127.0.0.1:0").unwrap();
        let broker = server.broker().clone();
        let addr = server.addr();
        {
            let link = connect_tcp(addr).unwrap();
            let send = |req: &ClientRequest, id: u64| link.send(&req.to_frame(id)).unwrap();
            send(
                &ClientRequest::QueueDeclare {
                    queue: "tasks".into(),
                    options: QueueOptions::default(),
                },
                1,
            );
            send(
                &ClientRequest::Publish {
                    exchange: "".into(),
                    routing_key: "tasks".into(),
                    body: crate::wire::Bytes::encode(&Value::str("work")),
                    props: Default::default(),
                    mandatory: true,
                },
                2,
            );
            send(
                &ClientRequest::Consume {
                    queue: "tasks".into(),
                    consumer_tag: "doomed".into(),
                    prefetch: 0,
                },
                3,
            );
            // Wait for the delivery to be in flight.
            let deadline = std::time::Instant::now() + Duration::from_secs(2);
            while broker.queue_unacked("tasks") != Some(1) {
                assert!(std::time::Instant::now() < deadline);
                std::thread::sleep(Duration::from_millis(5));
            }
            // Drop the socket without acking — simulated crash.
            link.close();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while broker.queue_depth("tasks") != Some(1) {
            assert!(std::time::Instant::now() < deadline, "message was not requeued");
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
    }
}
