//! Batched delivery: drain up to `batch` ready messages from a queue per
//! shard-lock acquisition and hand each connection its share as a single
//! multi-delivery unit ([`ServerMsg::DeliverBatch`]).
//!
//! Compared to the old one-message-per-lock pump this amortises the lock
//! acquisition, the per-connection channel send and (downstream) the
//! session's write syscall across the whole batch, while the `batch` bound
//! keeps any one drain from starving concurrent publishers to the same
//! shard.
//!
//! Assignment and channel-send happen under the shard lock, which is what
//! preserves per-queue FIFO delivery order when several threads pump the
//! same queue concurrently (sends never interleave out of assignment
//! order). Channel sends are non-blocking, so the lock hold stays short.

use std::sync::Arc;
use std::time::Instant;

use crate::broker::protocol::{Delivery, ServerMsg};
use crate::broker::queue::{DeadLettered, DeadReason, PendingDead};
use crate::broker::shard::ShardSet;
use crate::metrics::{Counter, Registry};

/// One connection's share of a drained batch, with its payload byte count
/// (egress bytes are only booked when the group's send lands).
struct Group {
    conn: u64,
    deliveries: Vec<Delivery>,
    tags: Vec<u64>,
    bytes: u64,
}

/// The delivery pump. Holds pre-resolved per-shard metric handles so the
/// hot path never touches the registry's name map.
pub struct Dispatcher {
    batch: usize,
    shard_delivered: Vec<Arc<Counter>>,
    shard_batches: Vec<Arc<Counter>>,
    delivered: Arc<Counter>,
    /// Egress payload bytes (props + body) handed to consumers.
    bytes_out: Arc<Counter>,
}

impl Dispatcher {
    pub fn new(batch: usize, nshards: usize, metrics: &Registry) -> Self {
        Dispatcher {
            batch: batch.max(1),
            shard_delivered: (0..nshards)
                .map(|i| metrics.counter(&format!("broker.shard.{i}.delivered")))
                .collect(),
            shard_batches: (0..nshards)
                .map(|i| metrics.counter(&format!("broker.shard.{i}.batches")))
                .collect(),
            delivered: metrics.counter("broker.delivered"),
            bytes_out: metrics.counter("broker.bytes_out_total"),
        }
    }

    /// Max deliveries handed out per lock acquisition.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Pump one queue until it runs dry (no ready messages or no consumer
    /// capacity), one bounded batch per shard-lock acquisition.
    ///
    /// Messages found expired during assignment come back as
    /// [`PendingDead`] — the caller (the core) dead-letters or retires
    /// them once no shard lock is held; the pump itself never touches the
    /// router or the WAL.
    #[must_use]
    pub fn pump(&self, shards: &ShardSet, qname: &str) -> Vec<PendingDead> {
        let shard = shards.shard_for(qname);
        let mut pending: Vec<PendingDead> = Vec::new();
        loop {
            let now = Instant::now();
            let assigned;
            let mut send_failed = false;
            let mut batch_bytes = 0u64;
            {
                let mut st = shard.lock();
                let (queues, delivery_index, conns, mut tags) = st.for_dispatch();
                let (assignments, qarc) = {
                    let Some(q) = queues.get_mut(qname) else { return pending };
                    // Per-connection backpressure: skip consumers whose
                    // connection reports an over-cap outbox (reactor path).
                    // Unknown connections pass — the send-failure branch
                    // below already handles genuinely dead ones, and the
                    // filter must not mask that requeue logic.
                    let assignments = q.assign_up_to_filtered(
                        now,
                        self.batch,
                        || tags.next(),
                        |conn| conns.get(&conn).is_none_or(|e| e.ready()),
                    );
                    let expired = q.drain_expired();
                    if !expired.is_empty() {
                        pending.extend(q.pend_dead(
                            expired
                                .into_iter()
                                .map(|m| DeadLettered {
                                    reason: DeadReason::Expired,
                                    message: m,
                                })
                                .collect(),
                        ));
                    }
                    (assignments, q.name.clone())
                };
                assigned = assignments.len();
                // Group the batch per connection, preserving per-connection
                // assignment order. Each group tracks its payload bytes so
                // egress is only counted for sends that actually landed
                // (failed sends are nacked back and redelivered later —
                // counting them here would double-book those bytes).
                let mut groups: Vec<Group> = Vec::new();
                for a in assignments {
                    // Interned handle: recording the delivery costs a
                    // refcount bump, not a per-delivery String.
                    delivery_index.insert(a.delivery_tag, qarc.clone());
                    let bytes = (a.message.body.len() + a.message.props.bytes().len()) as u64;
                    // Refcount bumps only — the body/props buffers are the
                    // publisher's original encode, shared with the queue's
                    // unacked copy and every other fanout recipient.
                    let delivery = Delivery {
                        consumer_tag: a.consumer_tag,
                        delivery_tag: a.delivery_tag,
                        redelivered: a.message.redelivered,
                        exchange: a.message.exchange.clone(),
                        routing_key: a.message.routing_key.clone(),
                        body: a.message.body.clone(),
                        props: a.message.props.clone(),
                        offset: a.offset,
                    };
                    match groups.iter_mut().find(|g| g.conn == a.connection) {
                        Some(g) => {
                            g.deliveries.push(delivery);
                            g.tags.push(a.delivery_tag);
                            g.bytes += bytes;
                        }
                        None => groups.push(Group {
                            conn: a.connection,
                            deliveries: vec![delivery],
                            tags: vec![a.delivery_tag],
                            bytes,
                        }),
                    }
                }
                for Group { conn, mut deliveries, tags: tags_of, bytes } in groups {
                    let sent = match conns.get(&conn) {
                        Some(entry) => {
                            if deliveries.len() == 1 {
                                entry.send(ServerMsg::Deliver(deliveries.pop().unwrap()))
                            } else {
                                entry.send(ServerMsg::DeliverBatch(deliveries))
                            }
                        }
                        None => false,
                    };
                    if sent {
                        batch_bytes += bytes;
                    } else {
                        // The connection's receiver is gone (session tearing
                        // down); the disconnect path will requeue whatever it
                        // still holds — put these back right away so nothing
                        // is stranded in the meantime. The attempt is not
                        // counted (the send never reached the consumer), so
                        // a dying connection can never push a message over
                        // its `max_delivery` cap from here.
                        send_failed = true;
                        if let Some(q) = queues.get_mut(qname) {
                            for t in &tags_of {
                                q.requeue_undelivered(*t);
                                delivery_index.remove(t);
                            }
                        }
                    }
                }
            }
            if assigned > 0 {
                self.delivered.add(assigned as u64);
                self.bytes_out.add(batch_bytes);
                self.shard_delivered[shard.index()].add(assigned as u64);
                self.shard_batches[shard.index()].inc();
            }
            if send_failed {
                // Requeued messages would be reassigned to the same dead
                // consumer on the next round — an unbounded hot spin. Stop;
                // the disconnect path removes the consumer and re-pumps, and
                // any later ack/publish re-triggers delivery too.
                return pending;
            }
            if assigned < self.batch {
                return pending; // queue ran dry (or out of consumer capacity)
            }
        }
    }
}
