//! Embedded broker: the single-machine deployment (paper: "scalable from
//! individual laptops ..."). Clients get a [`Link`] whose other half is
//! served by a thread inside this process; the protocol and semantics are
//! byte-identical to the TCP path, so everything above the link cannot
//! tell the difference.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::broker::core::BrokerHandle;
use crate::broker::heartbeat::HeartbeatMonitor;
use crate::broker::session::serve_link;
use crate::transport::link::inproc_pair;
use crate::transport::Link;

/// An in-process broker. Cheap to clone; the broker core is shared.
#[derive(Clone)]
pub struct InprocBroker {
    broker: BrokerHandle,
    sessions: Arc<Mutex<Vec<JoinHandle<()>>>>,
    _monitor: Arc<HeartbeatMonitor>,
}

impl Default for InprocBroker {
    fn default() -> Self {
        Self::new()
    }
}

impl InprocBroker {
    /// Transient embedded broker with a 50 ms heartbeat scan.
    pub fn new() -> Self {
        Self::with_broker(BrokerHandle::new())
    }

    /// Embed an existing broker core (e.g. one recovered from a WAL).
    pub fn with_broker(broker: BrokerHandle) -> Self {
        let monitor = HeartbeatMonitor::spawn(broker.clone(), Duration::from_millis(50));
        InprocBroker {
            broker,
            sessions: Arc::new(Mutex::new(Vec::new())),
            _monitor: Arc::new(monitor),
        }
    }

    /// Open a new client link to this broker.
    pub fn connect(&self) -> Arc<dyn Link> {
        let (client, server) = inproc_pair();
        let server: Arc<dyn Link> = Arc::new(server);
        let broker = self.broker.clone();
        let handle = std::thread::Builder::new()
            .name("kiwi-inproc-session".into())
            .spawn(move || serve_link(broker, server))
            .expect("spawn inproc session");
        let mut sessions = self.sessions.lock().unwrap();
        sessions.retain(|h| !h.is_finished());
        sessions.push(handle);
        Arc::new(client)
    }

    /// The shared broker core.
    pub fn broker(&self) -> &BrokerHandle {
        &self.broker
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::{ClientRequest, QueueOptions, ServerMsg};
    use crate::wire::{Frame, FrameType, Value};

    #[test]
    fn inproc_broker_serves_protocol() {
        let broker = InprocBroker::new();
        let link = broker.connect();
        link.send(
            &ClientRequest::QueueDeclare { queue: "q".into(), options: QueueOptions::default() }
                .to_frame(1),
        )
        .unwrap();
        let f = link.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(f.frame_type, FrameType::Data);
        assert!(matches!(
            ServerMsg::from_frame(&f).unwrap(),
            ServerMsg::Ok { req_id: 1, .. }
        ));
        link.send(&Frame::goodbye("done")).unwrap();
    }

    #[test]
    fn two_clients_share_state() {
        let broker = InprocBroker::new();
        let a = broker.connect();
        let b = broker.connect();
        a.send(
            &ClientRequest::QueueDeclare {
                queue: "shared".into(),
                options: QueueOptions::default(),
            }
            .to_frame(1),
        )
        .unwrap();
        a.recv_timeout(Duration::from_secs(2)).unwrap();
        // Client B publishes to the queue A declared.
        b.send(
            &ClientRequest::Publish {
                exchange: "".into(),
                routing_key: "shared".into(),
                body: crate::wire::Bytes::encode(&Value::str("x")),
                props: Default::default(),
                mandatory: true,
            }
            .to_frame(1),
        )
        .unwrap();
        let f = b.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(matches!(
            ServerMsg::from_frame(&f).unwrap(),
            ServerMsg::Ok { .. }
        ));
        assert_eq!(broker.broker().queue_depth("shared"), Some(1));
    }
}
